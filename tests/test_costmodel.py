"""The calibrated cost-model layer: coefficient tables, the typed
Infeasible verdict, the active-model switch, fitted-table IO, and the
Spearman metric the fitter/CI assert on.

Golden-value guards live in test_planner_nd / test_planner_autotune /
test_dist_planner (every ESTIMATE pick and dist crossover is pinned there);
this file covers the new surface the plan.py split introduced.
"""

import math
import warnings

import pytest

from repro.core.client import Problem
from repro.core.costmodel import (BACKEND_COEFFS, DEFAULT_COEFFICIENTS,
                                  DEFAULT_MODEL, CostCoefficients, CostModel,
                                  Infeasible, get_active_model, load_tables,
                                  model_for_device, save_tables,
                                  set_active_model, spearman, use_model)
from repro.core.plan import (Candidate, estimate_bytes_moved, estimate_choice,
                             fallback_chain, hbm_passes)


# ---------------------------------------------------------------------------
# default table = hand-written values, bit-for-bit
# ---------------------------------------------------------------------------
def test_default_table_reproduces_hand_written_model():
    # spot-check the literals the refactor tabulated (the full golden grid
    # is pinned by the planner tests); any drift here is a model change
    assert hbm_passes("xla", 1024) == 2.0
    assert hbm_passes("stockham", 1024) == 10.0          # log2(n) passes
    assert hbm_passes("stockham_pallas", 1024) == 1.0    # one fused pass
    assert hbm_passes("sixstep", 1 << 16) == 5.0
    p = Problem((64, 64, 64), "Outplace_Complex", "float")
    assert estimate_bytes_moved(p, Candidate("xla")) == 8388608.0
    assert estimate_choice(p).backend == "xla"


def test_round_trip_through_dict():
    c = CostCoefficients()
    assert CostCoefficients.from_dict(c.to_dict()) == c
    assert c == DEFAULT_COEFFICIENTS


def test_from_dict_warns_on_unknown_coefficient():
    with pytest.warns(UserWarning, match="unknown cost coefficients"):
        c = CostCoefficients.from_dict({"xla_smooth_passes": 3.0,
                                        "warp_drive_passes": 9.0})
    assert c.xla_smooth_passes == 3.0


# ---------------------------------------------------------------------------
# the typed Infeasible verdict
# ---------------------------------------------------------------------------
def test_infeasible_verdict_is_falsy_inf_with_reason():
    v = Infeasible("because")
    assert not v
    assert float(v) == float("inf")
    assert v.reason == "because"


def test_estimate_returns_verdict_numeric_view_is_inf():
    p = Problem((19 * 19,))                      # oddshape: no pow2 backends
    cand = Candidate("stockham")
    verdict = DEFAULT_MODEL.estimate(p, cand)
    assert isinstance(verdict, Infeasible)
    assert "stockham" in verdict.reason
    assert estimate_bytes_moved(p, cand) == float("inf")
    # feasible candidates return a plain float, never a verdict
    ok = DEFAULT_MODEL.estimate(p, Candidate("bluestein"))
    assert isinstance(ok, float) and math.isfinite(ok)


# ---------------------------------------------------------------------------
# scaled models + the active-model switch
# ---------------------------------------------------------------------------
def test_scaled_touches_only_the_backend_coefficients():
    m = DEFAULT_MODEL.scaled({"stockham": 3.0}, device_kind="test")
    assert m.coeffs.stockham_stage_passes == 3.0
    # everything outside the stockham group is untouched
    for name in (f for b, names in BACKEND_COEFFS.items() if b != "stockham"
                 for f in names):
        assert getattr(m.coeffs, name) == getattr(DEFAULT_COEFFICIENTS, name)
    assert m.device_kind == "test"
    # original model unchanged (frozen coefficients)
    assert DEFAULT_MODEL.coeffs == DEFAULT_COEFFICIENTS


def test_use_model_scopes_the_delegates():
    p = Problem((1024,))
    base = estimate_bytes_moved(p, Candidate("stockham_pallas"))
    heavy = DEFAULT_MODEL.scaled({"stockham_pallas": 100.0})
    with use_model(heavy):
        assert get_active_model() is heavy
        assert estimate_bytes_moved(p, Candidate("stockham_pallas")) \
            == pytest.approx(100.0 * base)
    assert get_active_model() is DEFAULT_MODEL
    assert estimate_bytes_moved(p, Candidate("stockham_pallas")) == base


def test_fitted_model_changes_estimate_pick_and_chain_order():
    # on the CI CPU the fitter massively up-prices the interpret-mode
    # Pallas kernels; emulate that and check ESTIMATE + fallback_chain
    # re-rank without any caller changes (the active-model contract)
    p = Problem((4096,))
    default_pick = estimate_choice(p).backend
    assert default_pick in {"stockham_pallas", "fourstep_pallas"}
    fitted = DEFAULT_MODEL.scaled(
        {b: 50.0 for b in ("stockham_pallas", "fourstep_pallas", "sixstep",
                           "chirpz_pallas", "dft")})
    with use_model(fitted):
        assert estimate_choice(p).backend != default_pick
        chain = fallback_chain(p)
        costs = [estimate_bytes_moved(p, c) for c in chain]
        assert costs == sorted(costs)


def test_set_active_model_none_restores_default():
    prev = set_active_model(DEFAULT_MODEL.scaled({"xla": 2.0}))
    try:
        assert get_active_model() is not DEFAULT_MODEL
    finally:
        set_active_model(None)
    assert get_active_model() is DEFAULT_MODEL
    assert prev is DEFAULT_MODEL


# ---------------------------------------------------------------------------
# versioned per-device tables
# ---------------------------------------------------------------------------
def test_save_load_tables_round_trip(tmp_path):
    path = str(tmp_path / "costmodel.json")
    fitted = DEFAULT_MODEL.scaled({"xla": 1.5, "bluestein": 0.25},
                                  device_kind="cpu")
    save_tables(path, {"cpu": fitted, "default": DEFAULT_MODEL},
                meta={"generated_by": "test"})
    loaded = load_tables(path)
    assert set(loaded) == {"cpu", "default"}
    assert loaded["cpu"].coeffs == fitted.coeffs
    assert loaded["default"].coeffs == DEFAULT_COEFFICIENTS
    assert "test" in loaded["cpu"].source


def test_load_tables_rejects_newer_schema(tmp_path):
    path = tmp_path / "costmodel.json"
    path.write_text('{"schema": 999, "tables": {}}')
    with pytest.raises(ValueError, match="schema"):
        load_tables(str(path))


def test_model_for_device_matching(tmp_path):
    path = str(tmp_path / "costmodel.json")
    save_tables(path, {
        "cpu": DEFAULT_MODEL.scaled({"xla": 2.0}, device_kind="cpu"),
        "nvidia": DEFAULT_MODEL.scaled({"xla": 3.0}, device_kind="nvidia"),
        "default": DEFAULT_MODEL})
    tables = load_tables(path)
    assert model_for_device("cpu", tables).coeffs.xla_smooth_passes == 4.0
    # case-insensitive prefix match finds the vendor table
    assert model_for_device("NVIDIA H100 80GB HBM3",
                            tables).coeffs.xla_smooth_passes == 6.0
    # unknown kinds fall back to the file's default table
    assert model_for_device("TPU v5e", tables).coeffs == DEFAULT_COEFFICIENTS
    # ...and to the hand-written model when the file has no default
    assert model_for_device("TPU v5e", {}) is DEFAULT_MODEL
    # a path is accepted directly
    assert model_for_device("cpu", path).coeffs.xla_smooth_passes == 4.0


# ---------------------------------------------------------------------------
# spearman (the fitter/CI metric)
# ---------------------------------------------------------------------------
def test_spearman_basic():
    assert spearman([1, 2, 3, 4], [10, 20, 30, 40]) == pytest.approx(1.0)
    assert spearman([1, 2, 3, 4], [40, 30, 20, 10]) == pytest.approx(-1.0)
    # monotone-invariant: rank correlation ignores the scale of the values
    assert spearman([1, 2, 3, 4], [1, 100, 1000, 10**6]) == pytest.approx(1.0)


def test_spearman_ties_get_average_ranks():
    # ties on both sides, still perfectly concordant
    assert spearman([1, 1, 2, 2], [5, 5, 9, 9]) == pytest.approx(1.0)
    r = spearman([1, 1, 2], [1, 2, 3])
    assert 0.0 < r < 1.0


def test_spearman_degenerate_cases():
    assert math.isnan(spearman([], []))
    assert math.isnan(spearman([1.0], [2.0]))
    assert math.isnan(spearman([3, 3, 3], [1, 2, 3]))   # zero rank variance
    with pytest.raises(ValueError):
        spearman([1, 2], [1])


# ---------------------------------------------------------------------------
# the fitter CLI (stdlib-only, runs against the committed BENCH data)
# ---------------------------------------------------------------------------
def _load_fitter():
    import importlib.util
    import os
    import sys
    spec = importlib.util.spec_from_file_location(
        "fit_costmodel", os.path.join(os.path.dirname(__file__), "..",
                                      "tools", "fit_costmodel.py"))
    mod = importlib.util.module_from_spec(spec)
    sys.modules["fit_costmodel"] = mod   # dataclasses needs the registration
    spec.loader.exec_module(mod)
    return mod


def test_fitter_on_committed_smoke_bench(tmp_path):
    import os
    fit = _load_fitter()
    root = os.path.join(os.path.dirname(__file__), "..")
    bench = os.path.join(root, "benchmarks", "baselines", "BENCH_smoke.json")
    out = str(tmp_path / "fitted.json")
    rc = fit.main([bench, "--out", out, "--assert-improves",
                   "--assert-kind", "cpu"])
    assert rc == 0
    tables = load_tables(out)
    assert "cpu" in tables
    assert tables["cpu"].coeffs != DEFAULT_COEFFICIENTS


def test_fitter_assertion_failure_is_nonzero(tmp_path):
    import os
    fit = _load_fitter()
    root = os.path.join(os.path.dirname(__file__), "..")
    bench = os.path.join(root, "benchmarks", "baselines", "BENCH_smoke.json")
    rc = fit.main([bench, "--assert-min-rho", "1.01", "--assert-kind", "cpu"])
    assert rc == 1


def test_roofline_fallback_tags_infeasible_rows():
    import importlib.util
    import os
    import sys
    spec = importlib.util.spec_from_file_location(
        "bench_compare", os.path.join(os.path.dirname(__file__), "..",
                                      "tools", "bench_compare.py"))
    bc = importlib.util.module_from_spec(spec)
    sys.modules["bench_compare"] = bc
    spec.loader.exec_module(bc)
    bc.ROOFLINE_FALLBACKS.clear()
    p = Problem((19 * 19,))                     # oddshape
    rec = {}
    # a row that ran but models as infeasible: tagged, logged, and still
    # gets a finite roofline from the 2x-signal-bytes algorithmic minimum
    bc._annotate_roofline(rec, p, Candidate("stockham"), 1e-3)
    assert "stockham" in rec["roofline_fallback"]
    assert rec["model_bytes"] == 2.0 * p.signal_bytes
    assert math.isfinite(rec["roofline_frac"]) and rec["roofline_frac"] > 0
    assert len(bc.ROOFLINE_FALLBACKS) == 1
    # feasible rows carry the model's own bytes and no tag
    rec2 = {}
    bc._annotate_roofline(rec2, p, Candidate("bluestein"), 1e-3)
    assert "roofline_fallback" not in rec2
    assert rec2["model_bytes"] == estimate_bytes_moved(p, Candidate("bluestein"))
    bc.ROOFLINE_FALLBACKS.clear()


# ---------------------------------------------------------------------------
# plan.py facade: the split must keep every historical import working
# ---------------------------------------------------------------------------
def test_plan_facade_reexports_the_split_modules():
    from repro.core import plan as plan_mod
    for name in ("BACKENDS", "DIST_BACKENDS", "Candidate", "CircuitBreaker",
                 "DIST_LINK_COST", "Infeasible", "CostModel",
                 "breaker_key", "problem_class", "candidates",
                 "backend_supports", "dist_supports", "estimate_choice",
                 "estimate_bytes_moved", "hbm_passes", "fallback_chain",
                 "use_model", "get_active_model", "set_active_model",
                 "_axis_elems", "_mixed_candidates", "_pencil_mesh_shapes"):
        assert hasattr(plan_mod, name), name


def test_deprecated_wisdom_generate_warns():
    from repro.core import wisdom as wisdom_mod
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        with pytest.raises(DeprecationWarning):
            wisdom_mod.generate([(8,)], path="/nonexistent/never-written")
