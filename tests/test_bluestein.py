"""Chirp-Z regression tests: the dtype-downcast bugfix (f64 input must stay
double precision), the host-side (n, dtype, direction) table cache (the
second un-jitted call does no host trig work), and the fused Pallas engine
selection behind the planner's ``chirpz_pallas`` backend."""

import numpy as np
import pytest
import jax.numpy as jnp

from helpers.accuracy import assert_rel_l2, rel_l2
from repro.fft import bluestein

RNG = np.random.default_rng(57)

ODD = 361  # 19^2: the paper's oddshape class


def rc(shape, dtype=np.complex64):
    return (RNG.standard_normal(shape) +
            1j * RNG.standard_normal(shape)).astype(dtype)


# --------------------------------------------------------------------------
# dtype mapping (the downcast bug): f32 -> c64, f64 -> c128
# --------------------------------------------------------------------------
def test_real_f64_input_keeps_double_precision():
    """Regression: float64 real data used to silently cast to complex64,
    losing double precision on every oddshape transform."""
    x = RNG.standard_normal(ODD)                       # float64
    y = bluestein.fft(jnp.asarray(x))
    assert y.dtype == jnp.complex128
    # and it is double-precision *accurate*, not just double-width
    assert_rel_l2(np.asarray(y), np.fft.fft(x), "double",
                  "c128 chirp-Z on an oddshape length")


def test_real_f32_input_maps_to_c64():
    x = RNG.standard_normal(ODD).astype(np.float32)
    y = bluestein.fft(jnp.asarray(x))
    assert y.dtype == jnp.complex64
    assert rel_l2(y, np.fft.fft(x.astype(np.float64))) < 1e-3


def test_complex_dtypes_pass_through():
    assert bluestein.fft(jnp.asarray(rc((4,)))).dtype == jnp.complex64
    assert bluestein.fft(
        jnp.asarray(rc((4,), np.complex128))).dtype == jnp.complex128


# --------------------------------------------------------------------------
# table cache: no host trig work on the second call
# --------------------------------------------------------------------------
def test_second_call_does_no_host_trig_work(monkeypatch):
    calls = []
    real_build = bluestein._build_tables

    def counting_build(n, m, dtype, inverse):
        calls.append((n, m, jnp.dtype(dtype).name, inverse))
        return real_build(n, m, dtype, inverse)

    monkeypatch.setattr(bluestein, "_build_tables", counting_build)
    bluestein._TABLES.clear()
    x = jnp.asarray(rc((2, 45)))
    y1 = bluestein.fft(x)            # un-jitted: builds the (45, c64) table
    y2 = bluestein.fft(x)            # cache hit: NO host trig work
    assert calls == [(45, 128, "complex64", False)]
    assert rel_l2(y1, y2) == 0.0
    # a new direction / dtype each build exactly one new entry
    bluestein.fft(y1, inverse=True)
    bluestein.fft(x.astype(jnp.complex128))
    bluestein.fft(x.astype(jnp.complex128))
    assert calls == [(45, 128, "complex64", False),
                     (45, 128, "complex64", True),
                     (45, 128, "complex128", False)]


def test_table_cache_is_bounded():
    """An unbounded cache of near-cap chirp tables would grow host RSS by
    hundreds of MB per distinct length; eviction keeps it capped."""
    bluestein._TABLES.clear()
    for n in range(20, 20 + bluestein._TABLES_MAX + 5):
        bluestein.chirp_tables(n, 64, jnp.complex64)
    assert len(bluestein._TABLES) == bluestein._TABLES_MAX
    # oldest entries were evicted, newest survive
    assert (20, 64, "complex64", False) not in bluestein._TABLES
    assert (20 + bluestein._TABLES_MAX + 4, 64, "complex64", False) \
        in bluestein._TABLES
    bluestein._TABLES.clear()


def test_cached_tables_are_host_arrays():
    """The cache must hold numpy arrays: a device value captured while
    tracing a jit would leak a tracer into every later call."""
    bluestein._TABLES.clear()
    import jax
    jax.jit(bluestein.fft)(jnp.asarray(rc((2, 19))))
    assert bluestein._TABLES
    for c, fb in bluestein._TABLES.values():
        assert isinstance(c, np.ndarray) and isinstance(fb, np.ndarray)


# --------------------------------------------------------------------------
# engine resolution + smooth-m padding
# --------------------------------------------------------------------------
def test_pallas_engine_pads_to_smooth_m_not_pow2():
    """The mixed-radix kernel convolves at the smallest 7-smooth m >= 2n-1
    — 729 = 3^6 for n=361 instead of pow2 1024 — the pow2-only engines
    keep next_pow2."""
    assert bluestein.resolve_engine(361, "stockham_pallas") == \
        ("stockham_pallas", 729)
    assert bluestein.resolve_engine(361, "stockham") == ("stockham", 1024)
    assert bluestein.resolve_engine(18432, "stockham_pallas") == \
        ("stockham_pallas", 36864)          # vs pow2 65536: 1.78x tighter
    # auto on hardware takes the fused kernel + smooth pad; interpret mode
    # (off-TPU conformance) keeps the staged jnp engine
    assert bluestein.resolve_engine(361, "auto") == ("stockham_pallas", 729)
    assert bluestein.resolve_engine(361, "auto", interpret=True) == \
        ("stockham", 1024)
    # numerics hold at the tighter (non-pow2) padded length
    x = rc((2, 361))
    got = bluestein.fft(jnp.asarray(x), engine="stockham_pallas",
                        interpret=True)
    assert rel_l2(got, np.fft.fft(x, axis=-1)) < 1e-3


# --------------------------------------------------------------------------
# fused Pallas engines (the chirpz_pallas backend's knob space)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("engine", ["stockham_pallas", "sixstep", "auto"])
@pytest.mark.parametrize("n", [19, 100, ODD])
def test_fused_engines_match_numpy(engine, n):
    x = rc((2, n))
    got = bluestein.fft(jnp.asarray(x), engine=engine, interpret=True)
    assert rel_l2(got, np.fft.fft(x, axis=-1)) < 1e-3
    back = bluestein.fft(got, inverse=True, engine=engine, interpret=True)
    assert rel_l2(back, x) < 1e-3


def test_fused_engine_c128_oddshape():
    x = rc((2, ODD), np.complex128)
    got = bluestein.fft(jnp.asarray(x), engine="auto", interpret=True)
    assert np.asarray(got).dtype == np.complex128
    assert rel_l2(got, np.fft.fft(x, axis=-1)) < 1e-8


def test_unknown_engine_rejected():
    with pytest.raises(ValueError, match="chirp engine"):
        bluestein.fft(jnp.asarray(rc((2, 5))), engine="fftw")
