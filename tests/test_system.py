"""End-to-end behaviour tests for the paper's system: the benchmark suite
measures an FFT client and an LM step through the same machinery, the
planner's wisdom survives a round trip, and the serving engine completes
batched requests."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.benchmark import Benchmark, BenchmarkConfig
from repro.core.client import Context
from repro.core.tree import build_tree, select
from repro.core.clients.jax_fft import XlaFFTClient
from repro.configs.base import get_config
from repro.models.model import Model


def test_fft_suite_end_to_end(tmp_path):
    """The paper's core loop: tree -> select -> run -> validated CSV."""
    nodes = build_tree([XlaFFTClient], [(64,), (16, 16)])
    nodes = select(nodes, "*/float/*/Outplace_Real")
    cfg = BenchmarkConfig(warmups=0, repetitions=2,
                          output=str(tmp_path / "r.csv"))
    writer = Benchmark(Context(), cfg).run_nodes(nodes)
    path = writer.save()
    vals = [r for r in writer.rows if r.op == "validate"]
    assert len(vals) == 2 and all(r.success for r in vals)
    body = open(path).read()
    assert "execute_forward" in body and "upload" in body


def test_lm_step_measured_like_an_fft_client():
    """DESIGN.md §3: the same timed-op discipline wraps a train step."""
    from repro.core.timer import timed
    cfg = get_config("qwen3-1.7b").reduced(n_layers=1)
    model = Model(cfg, remat=False)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.zeros((2, 16), jnp.int32)}
    fn = jax.jit(lambda p, b: model.loss_fn(p, b)[0])
    (_, t_compile) = timed(fn, params, batch)      # init_forward analogue
    loss, t_exec = timed(fn, params, batch)        # execute_forward analogue
    assert np.isfinite(float(loss))
    assert t_compile > t_exec  # planning dwarfs execution (paper Figs. 4/5)


def test_serve_engine_completes_requests():
    from repro.launch.serve import Request, ServeEngine
    cfg = get_config("qwen3-1.7b").reduced(n_layers=1)
    model = Model(cfg, remat=False)
    params = model.init_params(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, batch_slots=2, max_len=32)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, (4,)).astype(np.int32),
                    max_new=4) for i in range(3)]
    pending = list(reqs)
    for _ in range(100):
        while pending and engine.submit(pending[0]):
            pending.pop(0)
        if engine.step() == 0 and not pending:
            break
    assert all(r.done for r in reqs)
    assert all(len(r.out) >= 4 for r in reqs)
