"""Extents generators + classification (paper Fig. 7 extent classes):
powerof2/radix357/oddshape boundaries, rank handling, parse error paths,
and the sweep_extents dispatch the SuiteSpec sweeps use."""

import math

import pytest

from repro.core.extents import (SWEEP_CLASSES, classify, format_extents,
                                next_smooth, oddshape_extents, parse_extents,
                                powerof2_extents, radix357_extents,
                                sweep_extents, total_elems)


# --------------------------------------------------------------------------
# parse_extents error paths
# --------------------------------------------------------------------------
@pytest.mark.parametrize("bad", ["", "x", "12x-1", "0", "1x2x3x4", "axb",
                                 "12.5", "4x", "x32"])
def test_parse_extents_rejects(bad):
    with pytest.raises(ValueError, match="bad extents spec"):
        parse_extents(bad)


def test_parse_extents_accepts_case_and_roundtrip():
    assert parse_extents("128X64") == (128, 64)
    for spec in ("1", "1024", "32x32", "3x5x7"):
        assert format_extents(parse_extents(spec)) == spec.lower()


def test_total_elems():
    assert total_elems((4, 8, 2)) == 64
    assert total_elems(()) == 1 == math.prod(())


# --------------------------------------------------------------------------
# classify boundaries + rank handling
# --------------------------------------------------------------------------
def test_classify_powerof2_boundaries():
    assert classify((1,)) == "powerof2"           # 2^0
    assert classify((2,)) == "powerof2"
    assert classify((1024, 2, 64)) == "powerof2"  # every axis must be pow2


@pytest.mark.parametrize("ext", [(3,), (120,), (2, 3), (6, 10, 14), (960,)])
def test_classify_radix357(ext):
    assert classify(ext) == "radix357"


@pytest.mark.parametrize("ext", [(11,), (19,), (19 * 19,), (1024, 19),
                                 (2, 3, 23)])
def test_classify_oddshape(ext):
    # one non-{2,3,5,7}-smooth axis makes the whole shape oddshape
    assert classify(ext) == "oddshape"


# --------------------------------------------------------------------------
# generators
# --------------------------------------------------------------------------
def test_powerof2_extents_values_and_rank():
    assert list(powerof2_extents(1, 3, 5)) == [(8,), (16,), (32,)]
    assert list(powerof2_extents(3, 4, 4)) == [(16, 16, 16)]
    assert list(powerof2_extents(1, 5, 3)) == []   # empty range


def test_radix357_extents_terminates_above_32():
    # regression: the old v//8 skip for v >= 32 could step over every
    # remaining smooth number and never reach `count` (infinite loop)
    got = list(radix357_extents(1, count=4, start=96))
    assert got == [(96,), (98,), (100,), (105,)]


def test_radix357_extents_properties():
    got = list(radix357_extents(1, count=6, start=3))
    assert len(got) == 6
    sizes = [e[0] for e in got]
    assert sizes == sorted(sizes) and len(set(sizes)) == 6
    for ext in got:
        assert classify(ext) == "radix357"     # smooth but never pure pow2
    # rank handling: the size repeats along every axis
    got3 = list(radix357_extents(3, count=2, start=3))
    assert all(len(e) == 3 and len(set(e)) == 1 for e in got3)


def test_oddshape_extents_properties():
    got = list(oddshape_extents(2, count=4))
    assert len(got) == 4
    assert got[0] == (19, 19)
    for ext in got:
        assert classify(ext) == "oddshape"
    # count caps at the base list
    assert len(list(oddshape_extents(1, count=100))) == 8


# --------------------------------------------------------------------------
# sweep dispatch (what SuiteSpec sweeps call)
# --------------------------------------------------------------------------
def test_sweep_extents_dispatch():
    assert sweep_extents("powerof2", 1, min_exp=3, max_exp=4) == [(8,), (16,)]
    assert sweep_extents("radix357", 1, count=3) == \
        list(radix357_extents(1, count=3))
    assert sweep_extents("oddshape", 3, count=2) == \
        list(oddshape_extents(3, count=2))
    assert set(SWEEP_CLASSES) == {"powerof2", "radix357", "oddshape"}


def test_sweep_extents_errors():
    with pytest.raises(ValueError, match="unknown sweep class"):
        sweep_extents("fibonacci", 1)
    with pytest.raises(ValueError, match="requires"):
        sweep_extents("powerof2", 1, min_exp=3)       # max_exp missing
    with pytest.raises(ValueError, match="does not accept"):
        sweep_extents("oddshape", 1, start=5)         # start is radix357-only
    with pytest.raises(ValueError, match="rank"):
        sweep_extents("powerof2", 4, min_exp=1, max_exp=2)


def test_next_smooth():
    """Smallest 7-smooth integer >= v (the chirp-Z padding helper)."""
    assert next_smooth(1) == 1 and next_smooth(0) == 1
    assert next_smooth(37) == 40
    assert next_smooth(721) == 729                  # 3^6, beats pow2 1024
    assert next_smooth(13717) == 13720              # 2^3 * 5 * 7^3
    assert next_smooth(36863) == 36864              # vs next_pow2 = 65536
    for v in (2, 17, 100, 1000, 54321):
        m = next_smooth(v)
        assert m >= v and classify((m,)) in ("powerof2", "radix357")
    assert next_smooth(31, primes=(2,)) == 32       # custom prime set
