"""Distributed planning: slab/pencil/dist1d candidate enumeration gated on
the active mesh, the interconnect-aware cost model with its golden crossover
points, mesh-shaped wisdom records (legacy records still load), atomic
concurrent-tolerant wisdom writes, and the SuiteSpec device-count axis.

Pure planner/model tests — no fake-device mesh is spun up, so they run in
tier-1.  A stand-in with just ``.size`` is all the candidate enumeration
needs (numeric distributed checks live in test_distributed_fft.py and the
conformance subprocess sweep)."""

import json
import warnings

import pytest

from repro.core.client import Problem
from repro.core.plan import (Candidate, DIST_BACKENDS, _pencil_mesh_shapes,
                             candidates, dist_local_engine, dist_supports,
                             estimate_bytes_moved)
from repro.core.suite import SuiteSpec, dist_support_matrix
from repro.core.wisdom import Wisdom


class FakeMesh:
    """Enough mesh for the planner: candidate enumeration only reads
    ``.size`` (building the shard_map needs a real one)."""
    def __init__(self, size: int):
        self.size = size


# --------------------------------------------------------------------------
# candidate enumeration
# --------------------------------------------------------------------------
def test_no_mesh_no_dist_candidates():
    """Single-process runs see exactly the pre-PR candidate space."""
    for ext in ((4096,), (64, 64), (64, 64, 64)):
        backs = {c.backend for c in candidates(Problem(ext))}
        assert not backs & set(DIST_BACKENDS), ext


def test_single_device_mesh_adds_nothing():
    backs = {c.backend
             for c in candidates(Problem((64, 64, 64), "Outplace_Complex"),
                            mesh=FakeMesh(1))}
    assert not backs & set(DIST_BACKENDS)


def test_mesh_enumerates_sharded_decompositions():
    mesh = FakeMesh(8)
    keys = {c.key() for c in candidates(
        Problem((64, 64, 64), "Outplace_Complex"), mesh=mesh)}
    assert "slab[8]" in keys
    assert "pencil[2x4]" in keys            # most balanced factorization
    # rank-1: the four-step matrix decomposition
    keys1 = {c.key() for c in candidates(
        Problem((4096,), "Outplace_Complex"), mesh=mesh)}
    assert "dist1d[8]" in keys1
    # rank-2 gets slab only (pencil wants a third axis to keep local)
    keys2 = {c.key() for c in candidates(
        Problem((64, 64), "Outplace_Complex"), mesh=mesh)}
    assert "slab[8]" in keys2
    assert not any(k.startswith("pencil") for k in keys2)


def test_patient_sweeps_decomposition_and_local_engine():
    """PATIENT widens the distributed space on both knobs the tentpole
    names: alternate pencil mesh factorizations and forced local engines."""
    cands = candidates(Problem((64, 64, 64), "Outplace_Complex"),
                       patient=True, mesh=FakeMesh(8))
    keys = {c.key() for c in cands}
    assert len(keys) == len(cands)          # no duplicates
    assert {"pencil[2x4]", "pencil[4x2]"} <= keys
    locals_ = {c.opts().get("local") for c in cands
               if c.backend in DIST_BACKENDS and c.options}
    assert len(locals_) >= 1                # forced local-engine variants
    assert all(k for k in locals_)          # every knob names an engine


def test_dist_supports_gating():
    p3 = Problem((64, 64, 64), "Outplace_Complex")
    assert dist_supports("slab", p3, (8,))
    assert dist_supports("pencil", p3, (2, 4))
    # real kinds never shard: packed half-spectrum breaks a2a divisibility
    assert not dist_supports("slab", Problem((64, 64, 64), "Outplace_Real"),
                             (8,))
    # one device is pure overhead
    assert not dist_supports("slab", p3, (1,))
    # indivisible extents
    assert not dist_supports(
        "slab", Problem((65, 64, 64), "Outplace_Complex"), (8,))
    assert not dist_supports(
        "pencil", Problem((64, 63, 64), "Outplace_Complex"), (2, 4))
    # dist1d is rank-1 batch-1 only
    assert dist_supports(
        "dist1d", Problem((4096,), "Outplace_Complex"), (8,))
    assert not dist_supports(
        "dist1d", Problem((4096,), "Outplace_Complex", batch=4), (8,))
    assert not dist_supports("dist1d", p3, (8,))
    # pencil wants a 2-D mesh shape, slab a flat one
    assert not dist_supports("pencil", p3, (8,))
    assert not dist_supports("slab", p3, (2, 4))


def test_pencil_mesh_shapes():
    assert _pencil_mesh_shapes(8) == [(2, 4)]
    assert set(_pencil_mesh_shapes(8, patient=True)) == {(2, 4), (4, 2)}
    assert _pencil_mesh_shapes(16)[0] == (4, 4)
    assert _pencil_mesh_shapes(2) == []     # Pr >= 2 and Pc >= 2


# --------------------------------------------------------------------------
# interconnect-aware cost model: goldens + crossover
# --------------------------------------------------------------------------
def test_interconnect_cost_goldens_small_extent():
    """At (16,16,16) the a2a latency floor dominates: staying on one device
    is modeled cheapest, and the 1-collective slab undercuts the
    2-collective pencil."""
    p = Problem((16, 16, 16), "Outplace_Complex")
    xla = estimate_bytes_moved(p, Candidate("xla"))
    slab = estimate_bytes_moved(p, Candidate("slab", mesh=(8,)))
    pencil = estimate_bytes_moved(p, Candidate("pencil", mesh=(2, 4)))
    # slab: 7 local passes x 2 x 4 KiB/device + 1 a2a (4*4KiB + 1MiB floor)
    assert xla == 131072.0
    assert slab == 1122304.0
    assert pencil == 2187264.0
    assert xla < slab < pencil


def test_interconnect_cost_goldens_past_crossover():
    """At (64,64,64) x 8 devices the per-device shard shrink beats the
    link cost: both decompositions undercut the single-device plan."""
    p = Problem((64, 64, 64), "Outplace_Complex")
    xla = estimate_bytes_moved(p, Candidate("xla"))
    slab = estimate_bytes_moved(p, Candidate("slab", mesh=(8,)))
    pencil = estimate_bytes_moved(p, Candidate("pencil", mesh=(2, 4)))
    assert xla == 8388608.0
    assert slab == 5767168.0
    assert pencil == 7864320.0
    assert slab < pencil < xla


def test_dist1d_crossover():
    """Small 1-D: single-device wins.  At 2^22 the sharded four-step's
    1/P-sized local work wins despite two all_to_alls."""
    small = Problem((4096,), "Outplace_Complex")
    best_single = min(estimate_bytes_moved(small, c)
                      for c in candidates(small))
    assert best_single < estimate_bytes_moved(
        small, Candidate("dist1d", mesh=(8,)))
    big = Problem((1 << 22,), "Outplace_Complex")
    best_single = min(estimate_bytes_moved(big, c) for c in candidates(big))
    assert estimate_bytes_moved(
        big, Candidate("dist1d", mesh=(8,))) < best_single


def test_planner_picks_dist_only_past_crossover():
    """End-to-end candidate ranking on an 8-device mesh: the min-cost pick
    stays single-device at small extents and goes distributed at large."""
    mesh = FakeMesh(8)

    def best(problem):
        return min(candidates(problem, mesh=mesh),
                   key=lambda c: estimate_bytes_moved(problem, c))

    assert best(
        Problem((16, 16, 16), "Outplace_Complex")
    ).backend not in DIST_BACKENDS
    assert best(Problem((64, 64, 64), "Outplace_Complex")
                ).backend == "slab"


def test_infeasible_dist_candidate_costs_inf():
    p = Problem((64, 64, 64), "Outplace_Real")
    assert estimate_bytes_moved(p, Candidate("slab", mesh=(8,))) == \
        float("inf")


def test_dist_local_engine_minimizes_passes():
    from repro.core.plan import hbm_passes
    for n in (16, 64, 512, 4096):
        b = dist_local_engine(n)
        assert hbm_passes(b, n) == min(
            hbm_passes(bb, n) for bb in ("dft", "stockham", "fourstep",
                                         "stockham_pallas", "xla"))


# --------------------------------------------------------------------------
# mesh-shaped wisdom records
# --------------------------------------------------------------------------
def test_wisdom_roundtrips_mesh_field(tmp_path):
    wpath = str(tmp_path / "w.json")
    w = Wisdom(wpath, device_kind="testdev")
    problem = Problem((64, 64, 64), "Outplace_Complex")
    cand = Candidate("pencil", (("local", "stockham_pallas"),), mesh=(2, 4))
    w.record(problem, cand, scope="dist")
    w.save()
    rec = next(iter(json.load(open(wpath)).values()))
    assert rec["mesh"] == [2, 4]
    w2 = Wisdom(wpath, device_kind="testdev")
    got = w2.lookup(problem, scope="dist")
    assert got == cand
    assert got.key() == "pencil[2x4](local=stockham_pallas)"


def test_legacy_wisdom_records_still_load(tmp_path):
    """Pre-PR6 records have no ``mesh`` key — they must load with an empty
    mesh, and their serialized form must stay byte-stable (no mesh field
    sneaking into single-device records)."""
    wpath = str(tmp_path / "w.json")
    w = Wisdom(wpath, device_kind="testdev")
    problem = Problem((4096,), "Outplace_Complex")
    w.record(problem, Candidate("stockham_pallas", (("radix", 8),)))
    w.save()
    rec = next(iter(json.load(open(wpath)).values()))
    assert "mesh" not in rec
    got = Wisdom(wpath, device_kind="testdev").lookup(problem)
    assert got.mesh == ()
    assert got.key() == "stockham_pallas(radix=8)"


# --------------------------------------------------------------------------
# atomic, concurrent-tolerant wisdom writes
# --------------------------------------------------------------------------
def test_wisdom_save_is_atomic_and_leaves_no_temp(tmp_path):
    wpath = str(tmp_path / "w.json")
    w = Wisdom(wpath, device_kind="testdev")
    w.record(Problem((64,)), Candidate("dft"))
    w.save()
    assert json.load(open(wpath))           # complete document on disk
    leftovers = [p for p in tmp_path.iterdir() if p.name != "w.json"]
    assert leftovers == []                  # mkstemp temp replaced, not left


def test_concurrent_sessions_merge_on_save(tmp_path):
    """Two sessions share a wisdom path; the slower save must not clobber
    entries the faster one persisted (merge-on-save, ours win conflicts)."""
    wpath = str(tmp_path / "w.json")
    a = Wisdom(wpath, device_kind="testdev")
    b = Wisdom(wpath, device_kind="testdev")    # loaded before a saved
    pa, pb = Problem((64,)), Problem((128,))
    a.record(pa, Candidate("dft"))
    a.save()
    b.record(pb, Candidate("stockham"))
    b.save()                                    # must keep a's entry
    w = Wisdom(wpath, device_kind="testdev")
    assert w.lookup(pa) == Candidate("dft")
    assert w.lookup(pb) == Candidate("stockham")
    # conflict: the saving session's own (newer) selection wins
    b2 = Wisdom(wpath, device_kind="testdev")
    b2.record(pa, Candidate("fourstep"))
    b2.save()
    assert Wisdom(wpath, device_kind="testdev").lookup(pa) == \
        Candidate("fourstep")


def test_corrupt_wisdom_warns_and_loads_empty(tmp_path):
    """A torn/corrupt file from a crashed session must never take the
    benchmark down — warn, start empty, and the next save repairs it."""
    wpath = tmp_path / "w.json"
    wpath.write_text('{"truncated": ')
    with pytest.warns(UserWarning, match="unreadable wisdom"):
        w = Wisdom(str(wpath), device_kind="testdev")
    assert len(w) == 0
    w.record(Problem((64,)), Candidate("dft"))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")     # save re-reads the corrupt file
        w.save()
    assert Wisdom(str(wpath), device_kind="testdev").lookup(
        Problem((64,))) == Candidate("dft")


# --------------------------------------------------------------------------
# SuiteSpec device-count axis + the distributed support matrix
# --------------------------------------------------------------------------
def test_suitespec_device_counts_roundtrip():
    spec = SuiteSpec(clients=("DistFFTND",), extents=("64x64x64",),
                     device_counts=(1, 2, 4, 8), output=None)
    d = spec.to_dict()
    assert d["device_counts"] == [1, 2, 4, 8]
    spec2 = SuiteSpec.from_dict(json.loads(json.dumps(d)))
    assert spec2.device_counts == (1, 2, 4, 8)
    assert SuiteSpec.from_toml(spec.to_toml()).device_counts == (1, 2, 4, 8)
    with pytest.raises(ValueError, match="device_counts"):
        SuiteSpec(clients=("Planned",), extents=("64",), device_counts=(0,),
                  output=None)


def test_suitespec_without_device_counts_is_legacy_stable():
    spec = SuiteSpec(clients=("Planned",), extents=("64",), output=None)
    assert "device_counts" not in spec.to_dict()
    assert SuiteSpec.from_dict(spec.to_dict()).device_counts == ()


def test_dist_support_matrix_shape_and_claims():
    rows = dist_support_matrix(device_counts=(2, 4, 8))
    by = {}
    for r in rows:
        if r["supported"]:
            by.setdefault(r["backend"], set()).add((r["rank"], r["devices"]))
    # slab covers rank 2+3 where the leading extents divide; the rank-3
    # probe (4,4,8) stops dividing at 8 devices, the rank-2 one (8,16) not
    assert {(2, 2), (3, 2), (2, 4), (3, 4), (2, 8)} <= by["slab"]
    assert (3, 8) not in by["slab"]
    assert all(rank in (2, 3) for rank, _ in by["slab"])
    assert by["pencil"] == {(3, 4), (3, 8)}     # p=2 has no (Pr>=2, Pc>=2)
    assert all(rank == 1 for rank, _ in by["dist1d"])
    # complex-only: no real kind is ever claimed
    assert not any(r["supported"] for r in rows
                   if "Real" in r["kind"])
