"""Property tests for the fused Stockham kernel and the six-step path
(separate module: test_stockham_pallas.py must run without hypothesis)."""

import numpy as np
import pytest
import jax.numpy as jnp

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from helpers.accuracy import rel_l2
from repro.fft import sixstep
from repro.kernels.stockham_pallas import ops as sp_ops


@settings(max_examples=12, deadline=None)
@given(logn=st.integers(1, 12), radix=st.sampled_from([2, 4, 8]),
       seed=st.integers(0, 2**31 - 1))
def test_property_stockham_pallas_roundtrip(logn, radix, seed):
    n = 2 ** logn
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal((2, n)) +
         1j * rng.standard_normal((2, n))).astype(np.complex64)
    y = sp_ops.fft(jnp.asarray(x), radix=radix, interpret=True)
    back = sp_ops.fft(y, inverse=True, radix=radix, interpret=True)
    assert rel_l2(back, x) < 1e-3


@settings(max_examples=10, deadline=None)
@given(logn=st.integers(1, 12), radix=st.sampled_from([2, 4, 8]),
       inverse=st.booleans(), seed=st.integers(0, 2**31 - 1))
def test_property_stockham_pallas_matches_numpy(logn, radix, inverse, seed):
    n = 2 ** logn
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal((3, n)) +
         1j * rng.standard_normal((3, n))).astype(np.complex64)
    got = sp_ops.fft(jnp.asarray(x), inverse=inverse, radix=radix,
                     interpret=True)
    want = np.fft.ifft(x, axis=-1) if inverse else np.fft.fft(x, axis=-1)
    assert rel_l2(got, want) < 1e-3


@settings(max_examples=8, deadline=None)
@given(logn=st.integers(2, 14), seed=st.integers(0, 2**31 - 1))
def test_property_sixstep_roundtrip(logn, seed):
    n = 2 ** logn
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal((2, n)) +
         1j * rng.standard_normal((2, n))).astype(np.complex64)
    back = sixstep.fft(sixstep.fft(jnp.asarray(x), interpret=True),
                       inverse=True, interpret=True)
    assert rel_l2(back, x) < 1e-3
