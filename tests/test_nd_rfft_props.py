"""Property tests for the ND separable path (``repro.fft.nd``) and the
packed real transforms (``repro.fft.rfft``): axes-permutation invariance,
Hermitian symmetry of r2c output, linearity, and fused-vs-separable
equivalence.  (Importorskip-gated like test_stockham_pallas_props.py so the
suite runs where hypothesis is not installed.)"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from helpers.accuracy import rel_l2
from repro.fft import fourstep, nd, stockham
from repro.fft import rfft as rfft_mod

jax.config.update("jax_enable_x64", True)

#: pow2 shapes (stockham engine) and mixed-smooth shapes incl. odd last
#: extents (fourstep engine)
POW2_SHAPES = [(4, 8), (8, 4), (2, 4, 8), (8, 8, 8), (16, 4)]
SMOOTH_SHAPES = [(6, 10), (5, 8), (4, 9), (3, 4, 10), (2, 3, 5)]


def _rand(shape, seed, complex_=True):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(shape)
    if complex_:
        x = (x + 1j * rng.standard_normal(shape)).astype(np.complex64)
    else:
        x = x.astype(np.float32)
    return x


@settings(max_examples=15, deadline=None)
@given(si=st.integers(0, len(POW2_SHAPES) - 1), seed=st.integers(0, 2**31 - 1),
       perm_seed=st.integers(0, 2**31 - 1))
def test_property_fftn_axes_permutation_invariance(si, seed, perm_seed):
    """A separable ND transform is an unordered set of axis transforms: any
    axis-application order gives the same spectrum."""
    x = _rand(POW2_SHAPES[si], seed)
    axes = list(range(x.ndim))
    perm = list(np.random.default_rng(perm_seed).permutation(axes))
    base = np.asarray(nd.fftn(jnp.asarray(x), stockham.fft, axes=axes))
    permuted = np.asarray(nd.fftn(jnp.asarray(x), stockham.fft, axes=perm))
    assert rel_l2(permuted, base) < 1e-4
    assert rel_l2(base, np.fft.fftn(x)) < 1e-3


@settings(max_examples=15, deadline=None)
@given(si=st.integers(0, len(SMOOTH_SHAPES) - 1),
       seed=st.integers(0, 2**31 - 1))
def test_property_rfftn_hermitian_symmetry(si, seed):
    """r2c output of a real signal obeys Y[k] = conj(Y[-k mod shape]): the
    reconstructed full spectrum must equal the complex transform."""
    x = _rand(SMOOTH_SHAPES[si], seed, complex_=False)
    half = np.asarray(nd.rfftn(jnp.asarray(x), fourstep.fft))
    full = np.asarray(nd.fftn(jnp.asarray(x).astype(jnp.complex64),
                              fourstep.fft))
    n = x.shape[-1]
    assert half.shape[-1] == n // 2 + 1
    # stored half agrees with the full spectrum...
    assert rel_l2(half, full[..., : n // 2 + 1]) < 1e-3
    # ...and the dropped bins are the Hermitian mirror of the stored ones
    rev = full
    for ax in range(full.ndim):
        rev = np.roll(np.flip(rev, axis=ax), 1, axis=ax)
    assert rel_l2(full, np.conj(rev)) < 1e-3


@settings(max_examples=15, deadline=None)
@given(si=st.integers(0, len(POW2_SHAPES) - 1), seed=st.integers(0, 2**31 - 1),
       a=st.floats(-2, 2), b=st.floats(-2, 2))
def test_property_rfftn_linearity(si, seed, a, b):
    x = _rand(POW2_SHAPES[si], seed, complex_=False)
    y = _rand(POW2_SHAPES[si], seed + 1, complex_=False)
    lhs = np.asarray(nd.rfftn(jnp.asarray(a * x + b * y), stockham.fft))
    rhs = (a * np.asarray(nd.rfftn(jnp.asarray(x), stockham.fft)) +
           b * np.asarray(nd.rfftn(jnp.asarray(y), stockham.fft)))
    scale = max(1.0, abs(a) + abs(b))
    assert rel_l2(lhs, rhs) < 1e-3 * scale


@settings(max_examples=15, deadline=None)
@given(si=st.integers(0, len(SMOOTH_SHAPES) - 1),
       seed=st.integers(0, 2**31 - 1))
def test_property_rfftn_roundtrip(si, seed):
    """gearshifft validation invariant, odd last extents included."""
    shape = SMOOTH_SHAPES[si]
    x = _rand(shape, seed, complex_=False)
    spec = nd.rfftn(jnp.asarray(x), fourstep.fft)
    back = np.asarray(nd.irfftn(spec, shape, fourstep.fft))
    assert rel_l2(back, x) < 1e-3


@settings(max_examples=15, deadline=None)
@given(si=st.integers(0, len(POW2_SHAPES) - 1),
       seed=st.integers(0, 2**31 - 1))
def test_property_packed_fused_matches_separable(si, seed):
    """rfftn_packed over a whole-transform engine equals the separable
    per-axis packed path (the fused rank-2 kernel's correctness backbone)."""
    shape = POW2_SHAPES[si]
    rank = len(shape)
    x = _rand(shape, seed, complex_=False)

    def cfftn(z, inverse=False):
        return nd.fftn(z, stockham.fft, axes=tuple(range(-rank, 0)),
                       inverse=inverse)

    fused = np.asarray(rfft_mod.rfftn_packed(jnp.asarray(x), cfftn, rank))
    separable = np.asarray(nd.rfftn(jnp.asarray(x), stockham.fft))
    assert rel_l2(fused, separable) < 1e-3
    back = np.asarray(rfft_mod.irfftn_packed(jnp.asarray(fused), shape, cfftn))
    assert rel_l2(back, x) < 1e-3


@settings(max_examples=10, deadline=None)
@given(si=st.integers(0, len(POW2_SHAPES) - 1),
       seed=st.integers(0, 2**31 - 1))
def test_property_per_axis_engines_match_single_engine(si, seed):
    """ND-native planning invariant: a per-axis engine list (even a mixed
    one) computes the same spectrum as one engine applied to every axis."""
    shape = POW2_SHAPES[si]
    x = _rand(shape, seed)
    engines = [stockham.fft if i % 2 == 0 else fourstep.fft
               for i in range(len(shape))]
    mixed = np.asarray(nd.fftn(jnp.asarray(x), engines))
    single = np.asarray(nd.fftn(jnp.asarray(x), stockham.fft))
    assert rel_l2(mixed, single) < 1e-3
