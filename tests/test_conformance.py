"""Differential conformance matrix: every registered backend x all four
kinds x both precisions x rank-1/2/3 extents against ``numpy.fft``, plus
gearshifft-style roundtrip checks (``ifft(fft(x)) ~= x``, rel-L2 <= 1e-3
float / 1e-8 double — see ``helpers.accuracy``).

The cell set is derived from ``plan.backend_supports`` via
``suite.support_matrix`` — the same source of truth the planner and the
README table use — so a backend that silently drops a rank/kind it claims
breaks this module, and a backend that grows support is swept automatically.

Two tiers:
* fast subset (default, tier-1): every backend x kind pair once, ranks
  rotated so all three ranks are exercised per backend, float precision.
  Inplace/Outplace share the transform math, so each distinct
  (backend, extents, complex?, precision) computation is verified once and
  memoized across kinds.
* full matrix: every supported cell, both precisions — run by the dedicated
  CI job step via ``CONFORMANCE_FULL=1`` under the ``slow`` marker.
"""

from __future__ import annotations

import os
import zlib

import numpy as np
import pytest
import jax.numpy as jnp

from helpers.accuracy import assert_rel_l2, numpy_forward, rand_input
from repro.core.client import KINDS, PRECISIONS, Problem
from repro.core.plan import BACKENDS, Candidate, backend_supports
from repro.core.suite import SUPPORT_PROBE_EXTENTS, support_matrix
from repro.core.clients.jax_fft import build_forward, build_inverse

RANKS = sorted(SUPPORT_PROBE_EXTENTS)

#: One small probe per non-pow2 extent class (paper Fig. 7): 12 = 2^2*3 is
#: the radix357 canary (its packed real half, 6, is still 7-smooth), 19 the
#: oddshape one.  Every backend claiming support at these extents gets at
#: least one tier-1 cell per class, and the full matrix sweeps them across
#: kinds x precisions.
CLASS_PROBE_EXTENTS = {"radix357": (12,), "oddshape": (19,)}


def check_cell(backend: str, problem: Problem,
               _verified: dict = {}) -> None:
    """Differential + roundtrip check of one matrix cell.  Memoized on the
    computation actually performed — Inplace/Outplace kinds build identical
    transforms, so each is verified once per (extents, complex?, precision).
    """
    key = (backend, problem.extents, problem.complex_input, problem.precision)
    if key in _verified:
        return
    # stable per-cell seed (hash() varies with PYTHONHASHSEED; a failing
    # cell must reproduce with the same data on rerun)
    x = rand_input(problem, seed=zlib.crc32(repr(key).encode()))
    fwd = build_forward(problem, Candidate(backend))
    spec = np.asarray(fwd(jnp.asarray(x)))
    want = numpy_forward(problem, x)
    assert spec.shape == want.shape, \
        f"{backend} {problem.signature()}: shape {spec.shape} != {want.shape}"
    assert_rel_l2(spec, want, problem.precision,
                  f"{backend} {problem.signature()} forward")
    inv = build_inverse(problem, Candidate(backend))
    back = np.asarray(inv(jnp.asarray(spec)))
    assert_rel_l2(back, x, problem.precision,
                  f"{backend} {problem.signature()} roundtrip")
    _verified[key] = True


# ---------------------------------------------------------------------------
# fast subset (tier-1)
# ---------------------------------------------------------------------------
def _fast_cells() -> list[tuple[str, int, str]]:
    """Every backend x kind once, rank rotating with the cell index so all
    supported ranks get exercised per backend."""
    cells = []
    for bi, backend in enumerate(BACKENDS):
        for ki, kind in enumerate(KINDS):
            for off in range(len(RANKS)):
                rank = RANKS[(bi + ki + off) % len(RANKS)]
                problem = Problem(SUPPORT_PROBE_EXTENTS[rank], kind, "float")
                if backend_supports(backend, problem):
                    cells.append((backend, rank, kind))
                    break
    return cells


def test_fast_subset_covers_every_backend_kind_pair():
    assert len(_fast_cells()) == len(BACKENDS) * len(KINDS)


@pytest.mark.parametrize("backend,rank,kind", _fast_cells(),
                         ids=lambda v: str(v))
def test_conformance(backend, rank, kind):
    check_cell(backend, Problem(SUPPORT_PROBE_EXTENTS[rank], kind, "float"))


# ---------------------------------------------------------------------------
# fast non-pow2 extent classes (tier-1): radix357 + oddshape per backend
# ---------------------------------------------------------------------------
def _class_cells() -> list[tuple[str, str, str]]:
    """For every backend and every non-pow2 extent class it claims support
    for, one cell — kinds rotated with the backend index so real and
    complex paths (and the odd-length full-complex fallback) all run."""
    cells = []
    for bi, backend in enumerate(BACKENDS):
        for ci, (cls, ext) in enumerate(sorted(CLASS_PROBE_EXTENTS.items())):
            for off in range(len(KINDS)):
                kind = KINDS[(bi + ci + off) % len(KINDS)]
                if backend_supports(backend, Problem(ext, kind, "float")):
                    cells.append((backend, cls, kind))
                    break
    return cells


def test_class_cells_cover_the_fused_nonpow2_paths():
    """The new fast paths must claim (and therefore test) their classes:
    the mixed-radix kernel on radix357, the fused chirp on both."""
    covered = {(b, c) for b, c, _ in _class_cells()}
    assert ("stockham_pallas", "radix357") in covered
    assert ("chirpz_pallas", "radix357") in covered
    assert ("chirpz_pallas", "oddshape") in covered
    assert ("bluestein", "oddshape") in covered
    assert ("xla", "oddshape") in covered


@pytest.mark.parametrize("backend,cls,kind", _class_cells(),
                         ids=lambda v: str(v))
def test_conformance_extent_classes(backend, cls, kind):
    check_cell(backend, Problem(CLASS_PROBE_EXTENTS[cls], kind, "float"))


# ---------------------------------------------------------------------------
# full matrix (CI conformance job: CONFORMANCE_FULL=1, slow marker)
# ---------------------------------------------------------------------------
def _full_cells() -> list[tuple[str, tuple, str, str]]:
    """Every supported (backend, extents, kind, precision) cell: the pow2
    probes per rank plus one radix357 and one oddshape probe."""
    rows = list(support_matrix())
    for ext in CLASS_PROBE_EXTENTS.values():
        rows += support_matrix(probes={len(ext): ext})
    return [(r["backend"], r["extents"], r["kind"], r["precision"])
            for r in rows if r["supported"]]


@pytest.mark.slow
def test_conformance_full_matrix():
    if os.environ.get("CONFORMANCE_FULL", "") in ("", "0"):
        pytest.skip("full backend x kind x precision x rank matrix: set "
                    "CONFORMANCE_FULL=1 (the dedicated CI job step runs it)")
    failures = []
    cells = _full_cells()
    for backend, extents, kind, precision in cells:
        problem = Problem(extents, kind, precision)
        try:
            check_cell(backend, problem)
        except Exception as e:  # a raising cell must not abort the sweep:
            # the whole point is the aggregated N/M failure report
            failures.append(f"{backend}/{problem.signature()}: "
                            f"{type(e).__name__}: {e}")
    assert not failures, \
        f"{len(failures)}/{len(cells)} cells failed:\n" + "\n".join(failures)


# ---------------------------------------------------------------------------
# distributed cells: decomposition x kind x rank on a forced 4-device mesh
# ---------------------------------------------------------------------------
def test_conformance_distributed_cells():
    """The distributed extension of the matrix — slab/pencil/dist1d cells
    with planned local engines, natural order, differential + roundtrip.
    Runs in a subprocess: a process's XLA device count is fixed at first
    jax init, and the in-process smoke tests must keep seeing 1 device."""
    import subprocess
    import sys

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(root, "src")
    proc = subprocess.run(
        [sys.executable,
         os.path.join(root, "tests", "helpers", "dist_fft_check.py"),
         "conformance"],
        env=env, capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, \
        f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    assert "DISTRIBUTED CONFORMANCE CELLS PASSED" in proc.stdout


# ---------------------------------------------------------------------------
# the support matrix itself is part of the contract
# ---------------------------------------------------------------------------
def test_support_matrix_declares_expected_ranks():
    rows = support_matrix()
    by_backend: dict[str, set] = {}
    for r in rows:
        if r["supported"]:
            by_backend.setdefault(r["backend"], set()).add(r["rank"])
    for backend in BACKENDS:
        want = {2} if backend == "fft2_pallas" else set(RANKS)
        assert by_backend.get(backend, set()) == want, backend


def test_support_matrix_is_kind_and_precision_blind_at_pow2_probes():
    """Real kinds plan through the packed path on any complex backend, so at
    the pow2 probe extents no backend's support may depend on kind or
    precision."""
    rows = support_matrix()
    seen: dict[tuple, set] = {}
    for r in rows:
        seen.setdefault((r["backend"], r["rank"]), set()).add(r["supported"])
    assert all(len(v) == 1 for v in seen.values()), \
        {k: v for k, v in seen.items() if len(v) > 1}


def test_full_matrix_spans_all_dimensions():
    cells = _full_cells()
    assert {c[0] for c in cells} == set(BACKENDS)
    assert {len(c[1]) for c in cells} == set(RANKS)
    assert {c[2] for c in cells} == set(KINDS)
    assert {c[3] for c in cells} == set(PRECISIONS)
    # both non-pow2 class probes contribute supported cells
    exts = {c[1] for c in cells}
    assert set(CLASS_PROBE_EXTENTS.values()) <= exts
