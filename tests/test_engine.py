"""Engine-layer tests: Runner/OpSchedule, PlanCache, client registry,
streaming result sinks, and the CLI plumbing that ties them together."""

import csv
import json

import numpy as np
import pytest

from repro.core.benchmark import Benchmark, BenchmarkConfig
from repro.core.client import Context, Problem
from repro.core.plan import PlanCache, PlanRigor
from repro.core.registry import (client_names, get_client, register_client,
                                 registered_clients)
from repro.core.results import (COLUMNS, CsvSink, JsonlSink, ResultWriter,
                                Row, columns_for, open_sink)
from repro.core.schedule import FFT_SCHEDULE, OpSchedule, OpStep, Runner
from repro.core.tree import BenchNode, build_tree
from repro.core.wisdom import Wisdom
from repro.core.clients import jax_fft as jf
from repro.core.clients.dist_fft import DistFFT1DClient, DistFFTNDClient


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------
def test_registry_discovers_builtin_clients():
    names = client_names()
    for expected in ("XlaFFT", "Stockham", "FourStep", "Bluestein",
                     "Planned", "DistFFT1D", "DistFFTND"):
        assert expected in names
    assert get_client("XlaFFT") is jf.XlaFFTClient
    assert registered_clients()["DistFFT1D"] is DistFFT1DClient
    assert registered_clients()["DistFFTND"] is DistFFTNDClient


def test_registry_rejects_duplicate_name():
    @register_client("EngineTestClient")
    class A:
        pass

    # same class again: idempotent (modules may be re-imported)
    assert register_client("EngineTestClient")(A) is A

    with pytest.raises(ValueError, match="already registered"):
        @register_client("EngineTestClient")
        class B:
            pass


def test_registry_unknown_name_lists_known():
    with pytest.raises(KeyError, match="XlaFFT"):
        get_client("NoSuchClient")


# --------------------------------------------------------------------------
# plan cache
# --------------------------------------------------------------------------
def test_plan_cache_hit_miss_accounting():
    cache = PlanCache()
    calls = []

    def build():
        calls.append(1)
        return object()

    e1, ev1, _ = cache.executable("k1", build)
    e2, ev2, _ = cache.executable("k1", build)
    assert (ev1, ev2) == ("miss", "hit") and e1 is e2 and len(calls) == 1
    assert cache.stats.misses == 1 and cache.stats.hits == 1
    e3, ev3, _ = cache.executable("k2", build)
    assert ev3 == "miss" and e3 is not e1 and len(cache) == 2


def test_plan_cache_keys_on_device_and_candidate():
    p = Problem((64,))
    from repro.core.plan import Candidate
    k_cpu = PlanCache.executable_key("cpu", p, Candidate("xla"), "forward")
    k_tpu = PlanCache.executable_key("TPU v4", p, Candidate("xla"), "forward")
    k_cand = PlanCache.executable_key("cpu", p, Candidate("stockham"), "forward")
    k_dir = PlanCache.executable_key("cpu", p, Candidate("xla"), "inverse")
    assert len({k_cpu, k_tpu, k_cand, k_dir}) == 4
    # batch/precision/kind are part of the problem signature
    k_b2 = PlanCache.executable_key("cpu", Problem((64,), batch=2),
                                    Candidate("xla"), "forward")
    assert k_b2 != k_cpu


def test_plan_cache_memoizes_plan_selection():
    cache = PlanCache()
    made = []

    def make():
        made.append(1)
        return "the-plan"

    p1, ev1 = cache.plan("pk", make)
    p2, ev2 = cache.plan("pk", make)
    assert (p1, p2) == ("the-plan", "the-plan")
    assert (ev1, ev2) == ("miss", "hit") and len(made) == 1
    # None results (wisdom misses) are cached too
    pn, _ = cache.plan("pk-none", lambda: None)
    pn2, ev = cache.plan("pk-none", lambda: None)
    assert pn is None and pn2 is None and ev == "hit"


# --------------------------------------------------------------------------
# runner / schedule
# --------------------------------------------------------------------------
class _ToyClient:
    """Records the op order the Runner drives; 'download' returns run count."""

    instances = 0
    schedule = OpSchedule("toy", (
        OpStep("setup", "setup", bytes_method="setup_bytes"),
        OpStep("work", "work", needs_input=True),
        OpStep("fetch", "fetch", captures_output=True),
        OpStep("teardown", "teardown"),
    ))

    def __init__(self):
        type(self).instances += 1
        self.calls = []
        self.cache_events = {"work": "hit"}

    def setup(self):
        self.calls.append("setup")

    def setup_bytes(self):
        return 123

    def work(self, x):
        self.calls.append(("work", x))

    def fetch(self):
        self.calls.append("fetch")
        return np.full(3, type(self).instances)

    def teardown(self):
        self.calls.append("teardown")


def test_runner_drives_schedule_and_skips_warmups():
    _ToyClient.instances = 0
    seen = []
    runner = Runner(_ToyClient.schedule, warmups=2, repetitions=3)
    records, out = runner.run(lambda: _ToyClient(), host_input="payload",
                              on_record=seen.append)
    assert _ToyClient.instances == 5            # a fresh client per run
    assert len(records) == 3 and seen == records  # warmups unrecorded
    rec = records[0]
    assert set(rec.times) == {"setup", "work", "fetch", "teardown", "total"}
    assert rec.nbytes == {"setup": 123}
    assert rec.cache == {"work": "hit"}
    assert all(v >= 0 for v in rec.times.values())
    np.testing.assert_array_equal(out, np.full(3, 5))  # last run's output


def test_fft_schedule_matches_paper_sequence():
    assert FFT_SCHEDULE.op_names == (
        "allocate", "init_forward", "upload", "execute_forward",
        "init_inverse", "execute_inverse", "download", "destroy", "total")


@pytest.mark.parametrize("warmups", [0, 1])
def test_benchmark_zero_reps_reports_no_runs(tmp_path, warmups):
    # warmups=1 matters: warmup output must not be blessed as a result
    nodes = build_tree([jf.XlaFFTClient], [(16,)], kinds=("Outplace_Real",),
                       precisions=("float",))
    cfg = BenchmarkConfig(warmups=warmups, repetitions=0,
                          output=str(tmp_path / "r.csv"))
    writer = Benchmark(Context(), cfg).run_nodes(nodes)
    vals = [r for r in writer.rows if r.op == "validate"]
    assert len(vals) == 1 and vals[0].success is False
    assert "no runs executed" in vals[0].error
    assert "AttributeError" not in vals[0].error


# --------------------------------------------------------------------------
# plan cache through the benchmark: compile-once, hit/miss columns
# --------------------------------------------------------------------------
def test_benchmark_plan_cache_compiles_each_direction_once(tmp_path):
    nodes = build_tree([jf.XlaFFTClient], [(32,)], kinds=("Outplace_Real",),
                       precisions=("float",))
    cache = PlanCache()
    cfg = BenchmarkConfig(warmups=0, repetitions=5,
                          output=str(tmp_path / "r.csv"))
    writer = Benchmark(Context(), cfg, plan_cache=cache).run_nodes(nodes)
    # one (node, direction) executable compiled at most once
    assert cache.stats.misses == 2                    # forward + inverse
    assert cache.stats.hits == 2 * 4                  # 4 warm reps, both dirs
    events = {(r.run, r.op): r.plan_cache for r in writer.rows
              if r.op in ("init_forward", "init_inverse")}
    assert events[(0, "init_forward")] == "miss"
    assert events[(0, "init_inverse")] == "miss"
    for run in range(1, 5):
        assert events[(run, "init_forward")] == "hit"
        assert events[(run, "init_inverse")] == "hit"
    assert writer.columns[-1] == "plan_cache"
    # validation still passes with the cached executables
    assert all(r.success for r in writer.rows if r.op == "validate")


def test_warmup_cold_compile_still_emitted(tmp_path):
    """With warmups > 0 the cache's cold compile happens in a warmup run —
    its init ops must still appear (negative run index), or planning cost
    silently vanishes from the output."""
    nodes = build_tree([jf.XlaFFTClient], [(32,)], kinds=("Outplace_Real",),
                       precisions=("float",))
    cfg = BenchmarkConfig(warmups=2, repetitions=2,
                          output=str(tmp_path / "r.csv"))
    writer = Benchmark(Context(), cfg, plan_cache=PlanCache()).run_nodes(nodes)
    inits = [(r.run, r.op, r.plan_cache) for r in writer.rows
             if r.op in ("init_forward", "init_inverse")]
    assert (-2, "init_forward", "miss") in inits
    assert (-2, "init_inverse", "miss") in inits
    # the second warmup hit the cache and stays unrecorded
    assert not any(run == -1 for run, _, _ in inits)
    assert all(pc == "hit" for run, _, pc in inits if run >= 0)
    # warmup records carry ONLY the cold-compile ops, no execute/total rows
    assert not any(r.run < 0 and r.op not in ("init_forward", "init_inverse")
                   for r in writer.rows)


def test_csv_schema_unchanged_without_cache(tmp_path):
    out = str(tmp_path / "r.csv")
    nodes = build_tree([jf.XlaFFTClient], [(16,)], kinds=("Outplace_Real",),
                       precisions=("float",))
    cfg = BenchmarkConfig(warmups=0, repetitions=1, output=out)
    Benchmark(Context(), cfg).run_nodes(nodes).save()
    with open(out) as f:
        header = f.readline().strip()
    assert header == ",".join(COLUMNS)   # byte-for-byte seed column order


# --------------------------------------------------------------------------
# sinks
# --------------------------------------------------------------------------
def _rows():
    return [Row("lib", "cpu", "64", 1, "powerof2", "float", "Outplace_Real",
                "estimate", i, "execute_forward", 1.5 * (i + 1), 64, True, "")
            for i in range(3)]


def test_csv_sink_streams_rows(tmp_path):
    path = str(tmp_path / "s.csv")
    sink = CsvSink(path)
    rows = _rows()
    sink.add(rows[0])
    with open(path) as f:        # flushed before save(): header + first row
        assert len(f.readlines()) == 2
    for r in rows[1:]:
        sink.add(r)
    sink.add(Row("lib", "cpu", "64", 1, "powerof2", "float", "Outplace_Real",
                 "estimate", 0, "validate", 0.0, 0, False, "boom"))
    assert sink.save() == path
    assert sink.n_rows == 4 and sink.n_failures == 1
    with open(path) as f:
        data = list(csv.DictReader(f))
    assert len(data) == 4 and data[0]["op"] == "execute_forward"


def test_jsonl_sink_roundtrip_parity_with_csv(tmp_path):
    cols = columns_for(plan_cache=True)
    cpath, jpath = str(tmp_path / "p.csv"), str(tmp_path / "p.jsonl")
    csink, jsink = CsvSink(cpath, cols), JsonlSink(jpath, cols)
    for r in _rows():
        csink.add(r)
        jsink.add(r)
    csink.save(), jsink.save()
    with open(cpath) as f:
        creader = csv.reader(f)
        header = next(creader)
        crows = list(creader)
    jrows = [json.loads(line) for line in open(jpath)]
    assert header == cols
    assert all(list(j.keys()) == cols for j in jrows)   # same column order
    for c, j in zip(crows, jrows):
        assert c == [str(j[k]) for k in cols]           # same values
    assert isinstance(jrows[0]["success"], bool)        # native types survive
    assert isinstance(jrows[0]["time_ms"], float)


def test_open_sink_by_extension(tmp_path):
    assert isinstance(open_sink(str(tmp_path / "a.jsonl")), JsonlSink)
    assert isinstance(open_sink(str(tmp_path / "a.csv")), CsvSink)
    assert isinstance(open_sink(str(tmp_path / "weird.out")), CsvSink)
    assert isinstance(open_sink(str(tmp_path / "x.csv"), fmt="jsonl"), JsonlSink)
    with pytest.raises(ValueError):
        open_sink(str(tmp_path / "a.csv"), fmt="xml")


def test_result_writer_counts_and_headers(tmp_path):
    w = ResultWriter(str(tmp_path / "w.csv"))
    for r in _rows():
        w.add(r)
    assert w.n_rows == 3 and w.n_failures == 0
    assert w.to_csv_string().splitlines()[0] == ",".join(COLUMNS)


# --------------------------------------------------------------------------
# CLI integration
# --------------------------------------------------------------------------
def test_cli_jsonl_sink_with_plan_cache_column(tmp_path, capsys):
    from repro.core.cli import main
    out = str(tmp_path / "cli.jsonl")
    rc = main(["-e", "16", "--client", "XlaFFT", "--kinds", "Outplace_Real",
               "--precisions", "float", "--reps", "2", "--warmups", "0",
               "-o", out])
    assert rc == 0
    rows = [json.loads(line) for line in open(out)]
    inits = [r for r in rows if r["op"] == "init_forward"]
    assert [r["plan_cache"] for r in sorted(inits, key=lambda r: r["run"])] \
        == ["miss", "hit"]
    assert "plan cache:" in capsys.readouterr().out


def test_cli_no_plan_cache_restores_seed_schema(tmp_path):
    from repro.core.cli import main
    out = str(tmp_path / "cli.csv")
    rc = main(["-e", "16", "--client", "XlaFFT", "--kinds", "Outplace_Real",
               "--precisions", "float", "--reps", "1", "--warmups", "0",
               "--no-plan-cache", "-o", out])
    assert rc == 0
    with open(out) as f:
        assert f.readline().strip() == ",".join(COLUMNS)


def test_cli_wisdom_uses_discovered_device_kind(tmp_path):
    """Regression: CLI used to build Wisdom with device_kind='' so lookups
    never matched stores pre-generated with the real JAX device kind."""
    import jax
    from repro.core.cli import main
    from repro.core.plan import Candidate

    wpath = str(tmp_path / "wisdom.json")
    w = Wisdom(wpath, device_kind=jax.devices()[0].device_kind)
    problem = Problem((64,), "Outplace_Real", "float")
    w.record(problem, Candidate("xla"))
    w.save()

    out = str(tmp_path / "w.csv")
    rc = main(["-e", "64", "--client", "Planned", "--kinds", "Outplace_Real",
               "--precisions", "float", "--rigor", "wisdom_only",
               "--wisdom", wpath, "--reps", "1", "--warmups", "0", "-o", out])
    assert rc == 0
    with open(out) as f:
        rows = list(csv.DictReader(f))
    vals = [r for r in rows if r["op"] == "validate"]
    assert vals and all(r["success"] == "True" for r in vals), \
        [r["error"] for r in vals]   # NULL-plan failure == device-key mismatch


# --------------------------------------------------------------------------
# distributed FFT through the shared runner
# --------------------------------------------------------------------------
def test_dist_fft_client_through_benchmark(tmp_path):
    nodes = [BenchNode(DistFFT1DClient, Problem((64,), "Outplace_Complex",
                                                "float"))]
    cache = PlanCache()
    cfg = BenchmarkConfig(warmups=0, repetitions=2,
                          output=str(tmp_path / "d.csv"))
    writer = Benchmark(Context(), cfg, plan_cache=cache).run_nodes(nodes)
    vals = [r for r in writer.rows if r.op == "validate"]
    assert vals and all(r.success for r in vals), [r.error for r in vals]
    assert cache.stats.misses == 2 and cache.stats.hits == 2
    # infeasible problems are recorded failures, not suite aborts
    bad = [BenchNode(DistFFT1DClient, Problem((32, 32), "Outplace_Complex",
                                              "float"))]
    writer2 = Benchmark(Context(), BenchmarkConfig(
        warmups=0, repetitions=1, output=str(tmp_path / "d2.csv"))).run_nodes(bad)
    v2 = [r for r in writer2.rows if r.op == "validate"]
    assert v2 and not v2[0].success and "rank-1" in v2[0].error


def test_dist_fftnd_client_through_benchmark(tmp_path):
    """The ND client degrades gracefully to one device (the P=1 slab is the
    in-process identity-collective path); real meshes are exercised by the
    subprocess conformance sweep."""
    nodes = [BenchNode(DistFFTNDClient, Problem((8, 8, 16),
                                                "Outplace_Complex", "float"))]
    writer = Benchmark(Context(), BenchmarkConfig(
        warmups=0, repetitions=2,
        output=str(tmp_path / "nd.csv"))).run_nodes(nodes)
    vals = [r for r in writer.rows if r.op == "validate"]
    assert vals and all(r.success for r in vals), [r.error for r in vals]
    # constraint violations are recorded failures, not suite aborts
    bad = [BenchNode(DistFFTNDClient, Problem((64,), "Outplace_Complex",
                                              "float"))]
    writer2 = Benchmark(Context(), BenchmarkConfig(
        warmups=0, repetitions=1, output=str(tmp_path / "nd2.csv"))).run_nodes(bad)
    v2 = [r for r in writer2.rows if r.op == "validate"]
    assert v2 and not v2[0].success and "rank-2/3" in v2[0].error
