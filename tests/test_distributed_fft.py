"""Distributed FFT tests — run in a subprocess so the fake-device XLA_FLAGS
never leak into this test process (smoke tests must see 1 device)."""

import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_distributed_fft_on_8_devices():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", "helpers", "dist_fft_check.py")],
        env=env, capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    assert "ALL DISTRIBUTED CHECKS PASSED" in proc.stdout
