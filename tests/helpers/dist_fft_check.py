"""Subprocess helper: validate distributed FFTs on 8 fake host devices.

Run as:  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
         PYTHONPATH=src python tests/helpers/dist_fft_check.py
Exits 0 on success; prints the failing check otherwise.
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np              # noqa: E402
import jax                      # noqa: E402
import jax.numpy as jnp         # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.fft import distributed as dist  # noqa: E402


def check_1d_single_axis():
    mesh = jax.make_mesh((8,), ("data",))
    n = 4096
    rng = np.random.default_rng(0)
    x = (rng.standard_normal(n) + 1j * rng.standard_normal(n)).astype(np.complex64)
    xd = jax.device_put(jnp.asarray(x), NamedSharding(mesh, P("data")))
    fn, (n1, n2) = dist.make_fft1d(mesh, "data", n)
    with mesh:
        y = np.asarray(fn(xd))
    got = np.asarray(dist.transposed_to_natural(jnp.asarray(y), n1, n2))
    want = np.fft.fft(x)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3 * np.sqrt(n))
    # inverse round trip: inverse on transposed layout with swapped factors
    fn_inv, _ = dist.make_fft1d(mesh, "data", n, inverse=True)
    print("  1d single-axis ok")


def check_1d_multi_axis():
    mesh = jax.make_mesh((2, 4), ("pod", "data"))
    n = 2048
    rng = np.random.default_rng(1)
    x = (rng.standard_normal(n) + 1j * rng.standard_normal(n)).astype(np.complex64)
    xd = jax.device_put(jnp.asarray(x), NamedSharding(mesh, P(("pod", "data"))))
    fn, (n1, n2) = dist.make_fft1d(mesh, ("pod", "data"), n)
    with mesh:
        y = np.asarray(fn(xd))
    got = np.asarray(dist.transposed_to_natural(jnp.asarray(y), n1, n2))
    np.testing.assert_allclose(got, np.fft.fft(x), rtol=2e-3, atol=2e-3 * np.sqrt(n))
    print("  1d multi-axis ok")


def check_3d():
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    shape = (16, 8, 32)
    rng = np.random.default_rng(2)
    x = (rng.standard_normal(shape) + 1j * rng.standard_normal(shape)).astype(np.complex64)
    sh = NamedSharding(mesh, P("data", "model", None))
    xd = jax.device_put(jnp.asarray(x), sh)
    fn = dist.make_fft3d(mesh, "data", "model", shape)
    with mesh:
        y = np.asarray(fn(xd))
    want = np.fft.fftn(x)
    np.testing.assert_allclose(y, want, rtol=2e-3, atol=2e-3 * np.sqrt(np.prod(shape)))
    # inverse roundtrip through the canonical layout
    fn_inv = dist.make_fft3d(mesh, "data", "model", shape, inverse=True)
    with mesh:
        back = np.asarray(fn_inv(jax.device_put(jnp.asarray(y), sh)))
    np.testing.assert_allclose(back, x, rtol=2e-3, atol=2e-3)
    print("  3d pencil ok (+roundtrip)")


def check_3d_transposed():
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    shape = (8, 8, 16)
    rng = np.random.default_rng(3)
    x = (rng.standard_normal(shape) + 1j * rng.standard_normal(shape)).astype(np.complex64)
    xd = jax.device_put(jnp.asarray(x), NamedSharding(mesh, P("data", "model", None)))
    fn = dist.make_fft3d(mesh, "data", "model", shape, keep_transposed=True)
    with mesh:
        y = np.asarray(fn(xd))
    np.testing.assert_allclose(y, np.fft.fftn(x), rtol=2e-3,
                               atol=2e-3 * np.sqrt(np.prod(shape)))
    print("  3d transposed-out ok")


def check_3d_multipod():
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    shape = (16, 8, 8)
    rng = np.random.default_rng(4)
    x = (rng.standard_normal(shape) + 1j * rng.standard_normal(shape)).astype(np.complex64)
    sh = NamedSharding(mesh, P(("pod", "data"), "model", None))
    xd = jax.device_put(jnp.asarray(x), sh)
    fn = dist.make_fft3d(mesh, ("pod", "data"), "model", shape)
    with mesh:
        y = np.asarray(fn(xd))
    np.testing.assert_allclose(y, np.fft.fftn(x), rtol=2e-3,
                               atol=2e-3 * np.sqrt(np.prod(shape)))
    print("  3d multi-pod axes ok")


if __name__ == "__main__":
    assert jax.device_count() == 8, f"need 8 host devices, got {jax.device_count()}"
    check_1d_single_axis()
    check_1d_multi_axis()
    check_3d()
    check_3d_transposed()
    check_3d_multipod()
    print("ALL DISTRIBUTED CHECKS PASSED")
