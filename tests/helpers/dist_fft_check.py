"""Subprocess helper: validate distributed FFTs on fake host devices.

Run as:  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
         PYTHONPATH=src python tests/helpers/dist_fft_check.py
Exits 0 on success; prints the failing check otherwise.

``... dist_fft_check.py conformance`` instead sweeps the distributed
conformance cells (decomposition x kind x rank, planned local engines,
natural order, forward differential + roundtrip) over however many devices
the process was forced to — the distributed extension of
test_conformance.py's matrix.
"""

import os
import sys
import zlib

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np              # noqa: E402
import jax                      # noqa: E402
import jax.numpy as jnp         # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.fft import distributed as dist  # noqa: E402


def check_1d_single_axis():
    mesh = jax.make_mesh((8,), ("data",))
    n = 4096
    rng = np.random.default_rng(0)
    x = (rng.standard_normal(n) + 1j * rng.standard_normal(n)).astype(np.complex64)
    xd = jax.device_put(jnp.asarray(x), NamedSharding(mesh, P("data")))
    fn, (n1, n2) = dist.make_fft1d(mesh, "data", n)
    with mesh:
        y = np.asarray(fn(xd))
    got = np.asarray(dist.transposed_to_natural(jnp.asarray(y), n1, n2))
    want = np.fft.fft(x)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3 * np.sqrt(n))
    # inverse round trip: inverse on transposed layout with swapped factors
    fn_inv, _ = dist.make_fft1d(mesh, "data", n, inverse=True)
    print("  1d single-axis ok")


def check_1d_multi_axis():
    mesh = jax.make_mesh((2, 4), ("pod", "data"))
    n = 2048
    rng = np.random.default_rng(1)
    x = (rng.standard_normal(n) + 1j * rng.standard_normal(n)).astype(np.complex64)
    xd = jax.device_put(jnp.asarray(x), NamedSharding(mesh, P(("pod", "data"))))
    fn, (n1, n2) = dist.make_fft1d(mesh, ("pod", "data"), n)
    with mesh:
        y = np.asarray(fn(xd))
    got = np.asarray(dist.transposed_to_natural(jnp.asarray(y), n1, n2))
    np.testing.assert_allclose(got, np.fft.fft(x), rtol=2e-3, atol=2e-3 * np.sqrt(n))
    print("  1d multi-axis ok")


def check_3d():
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    shape = (16, 8, 32)
    rng = np.random.default_rng(2)
    x = (rng.standard_normal(shape) + 1j * rng.standard_normal(shape)).astype(np.complex64)
    sh = NamedSharding(mesh, P("data", "model", None))
    xd = jax.device_put(jnp.asarray(x), sh)
    fn = dist.make_fft3d(mesh, "data", "model", shape)
    with mesh:
        y = np.asarray(fn(xd))
    want = np.fft.fftn(x)
    np.testing.assert_allclose(y, want, rtol=2e-3, atol=2e-3 * np.sqrt(np.prod(shape)))
    # inverse roundtrip through the canonical layout
    fn_inv = dist.make_fft3d(mesh, "data", "model", shape, inverse=True)
    with mesh:
        back = np.asarray(fn_inv(jax.device_put(jnp.asarray(y), sh)))
    np.testing.assert_allclose(back, x, rtol=2e-3, atol=2e-3)
    print("  3d pencil ok (+roundtrip)")


def check_3d_transposed():
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    shape = (8, 8, 16)
    rng = np.random.default_rng(3)
    x = (rng.standard_normal(shape) + 1j * rng.standard_normal(shape)).astype(np.complex64)
    xd = jax.device_put(jnp.asarray(x), NamedSharding(mesh, P("data", "model", None)))
    fn = dist.make_fft3d(mesh, "data", "model", shape, keep_transposed=True)
    with mesh:
        y = np.asarray(fn(xd))
    np.testing.assert_allclose(y, np.fft.fftn(x), rtol=2e-3,
                               atol=2e-3 * np.sqrt(np.prod(shape)))
    print("  3d transposed-out ok")


def check_3d_multipod():
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    shape = (16, 8, 8)
    rng = np.random.default_rng(4)
    x = (rng.standard_normal(shape) + 1j * rng.standard_normal(shape)).astype(np.complex64)
    sh = NamedSharding(mesh, P(("pod", "data"), "model", None))
    xd = jax.device_put(jnp.asarray(x), sh)
    fn = dist.make_fft3d(mesh, ("pod", "data"), "model", shape)
    with mesh:
        y = np.asarray(fn(xd))
    np.testing.assert_allclose(y, np.fft.fftn(x), rtol=2e-3,
                               atol=2e-3 * np.sqrt(np.prod(shape)))
    print("  3d multi-pod axes ok")


def _rel_l2(got, want):
    return np.linalg.norm(got - want) / max(np.linalg.norm(want), 1e-30)


def check_1d_natural_roundtrip():
    """Satellite: the inverse consumes natural order symmetrically — pinned
    c64 (and, below, c128) round-trip tolerances without any host-side
    reordering in either direction."""
    from repro.launch.mesh import flat_mesh

    mesh = flat_mesh()
    n, p = 4096, jax.device_count()
    rng = np.random.default_rng(10)
    x = (rng.standard_normal(n) + 1j * rng.standard_normal(n)).astype(np.complex64)
    sh = NamedSharding(mesh, P("data"))
    xd = jax.device_put(jnp.asarray(x), sh)
    fwd, _ = dist.make_fft1d(mesh, "data", n, natural=True)
    inv, _ = dist.make_ifft1d(mesh, "data", n, natural=True)
    y = fwd(xd)
    assert _rel_l2(np.asarray(y), np.fft.fft(x)) < 1e-5   # already natural
    back = np.asarray(inv(jax.device_put(y, sh)))
    assert _rel_l2(back, x) < 1e-5, _rel_l2(back, x)
    # transposed layout roundtrips too (the default cheap path)
    fwd_t, _ = dist.make_fft1d(mesh, "data", n)
    inv_t, _ = dist.make_ifft1d(mesh, "data", n)
    back = np.asarray(inv_t(jax.device_put(fwd_t(xd), sh)))
    assert _rel_l2(back, x) < 1e-5, _rel_l2(back, x)
    print(f"  1d natural+transposed roundtrip ok (p={p})")


def check_1d_roundtrip_c128():
    """Double precision pins the asymmetry fix at c128 tolerance.  Runs
    LAST: enabling x64 affects constant dtypes in later traces."""
    jax.config.update("jax_enable_x64", True)
    from repro.launch.mesh import flat_mesh

    mesh = flat_mesh()
    n = 4096
    rng = np.random.default_rng(11)
    x = (rng.standard_normal(n) + 1j * rng.standard_normal(n)).astype(np.complex128)
    sh = NamedSharding(mesh, P("data"))
    xd = jax.device_put(jnp.asarray(x), sh)
    for natural in (False, True):
        fwd, _ = dist.make_fft1d(mesh, "data", n, natural=natural)
        inv, _ = dist.make_ifft1d(mesh, "data", n, natural=natural)
        back = np.asarray(inv(jax.device_put(fwd(xd), sh)))
        assert back.dtype == np.complex128
        assert _rel_l2(back, x) < 1e-12, (natural, _rel_l2(back, x))
    print("  1d c128 roundtrip ok (both layouts)")


def _cell_mesh(backend, mesh_shape):
    from repro.launch.mesh import flat_mesh, reshaped_mesh

    names = ("d0", "d1")[:len(mesh_shape)]
    return reshaped_mesh(flat_mesh(), mesh_shape, names)


def check_conformance_cells():
    """The distributed conformance matrix: every (decomposition, kind, rank)
    cell dist_supports claims on this host's mesh, run through planner-
    selected local engines with natural-order output — forward differential
    against numpy + inverse roundtrip, exactly like check_cell for the
    single-device backends.  Real kinds must claim nothing."""
    from repro.core.client import KINDS, Problem
    from repro.core.plan import (Candidate, DIST_BACKENDS,
                                 _pencil_mesh_shapes, dist_supports)
    from repro.core.clients.dist_fft import dist_engines

    p_dev = jax.device_count()
    probes = {1: (1024,), 2: (16, 16), 3: (8, 8, 16)}
    cells, refused = [], 0
    for backend in DIST_BACKENDS:
        for rank, ext in sorted(probes.items()):
            for kind in KINDS:
                problem = Problem(ext, kind, "float")
                shapes = ([(p_dev,)] if backend != "pencil"
                          else _pencil_mesh_shapes(p_dev))
                shape = shapes[0] if shapes else (p_dev,)
                if dist_supports(backend, problem, shape):
                    cells.append((backend, problem, shape))
                else:
                    refused += 1
                    assert "Complex" not in kind or (
                        (backend, rank) not in
                        {("dist1d", 1), ("slab", 2), ("slab", 3),
                         ("pencil", 3)}), (backend, kind, rank)
    # every complex kind x claimed rank is a cell; no real kind ever is
    assert len(cells) == 8, [c[:1] + (c[1].signature(),) for c in cells]
    assert all(c[1].complex_input for c in cells)

    done = set()
    for backend, problem, mesh_shape in cells:
        key = (backend, problem.extents)    # kinds share the transform math
        if key in done:
            continue
        done.add(key)
        mesh = _cell_mesh(backend, mesh_shape)
        cand = Candidate(backend, mesh=mesh_shape)
        engines = dist_engines(problem, cand)
        rng = np.random.default_rng(zlib.crc32(repr(key).encode()))
        x = (rng.standard_normal(problem.extents)
             + 1j * rng.standard_normal(problem.extents)).astype(np.complex64)
        if backend == "dist1d":
            n = problem.extents[0]
            fwd, _ = dist.make_fft1d(mesh, "d0", n, natural=True,
                                     engines=engines)
            inv, _ = dist.make_ifft1d(mesh, "d0", n, natural=True,
                                      engines=engines)
            sh_in = sh_out = NamedSharding(mesh, P("d0"))
            xb = x
        else:
            if backend == "slab":
                fwd, in_spec, out_spec = dist.make_slab_fftnd(
                    mesh, "d0", problem.extents, natural=True,
                    engines=engines)
                inv, _, _ = dist.make_slab_fftnd(
                    mesh, "d0", problem.extents, inverse=True, natural=True,
                    engines=engines)
            else:
                fwd, in_spec, out_spec = dist.make_pencil_fftnd(
                    mesh, "d0", "d1", problem.extents, natural=True,
                    engines=engines)
                inv, _, _ = dist.make_pencil_fftnd(
                    mesh, "d0", "d1", problem.extents, inverse=True,
                    natural=True, engines=engines)
            sh_in = NamedSharding(mesh, in_spec)
            sh_out = NamedSharding(mesh, out_spec)
            xb = x[None]                    # (batch=1, *extents)
        xd = jax.device_put(jnp.asarray(xb), sh_in)
        y = fwd(xd)
        want = np.fft.fft(x) if problem.rank == 1 else np.fft.fftn(x)
        got = np.asarray(y).reshape(want.shape)
        assert _rel_l2(got, want) < 1e-3, \
            (backend, problem.signature(), _rel_l2(got, want))
        back = np.asarray(inv(jax.device_put(y, sh_out))).reshape(x.shape)
        assert _rel_l2(back, x) < 1e-3, \
            (backend, problem.signature(), _rel_l2(back, x))
        print(f"  cell {backend}[{'x'.join(map(str, mesh_shape))}] "
              f"{problem.signature()} ok")
    print(f"ALL {len(done)} DISTRIBUTED CONFORMANCE CELLS PASSED "
          f"({len(cells)} kind cells, {refused} refused)")


if __name__ == "__main__":
    if "conformance" in sys.argv[1:]:
        assert jax.device_count() >= 4, \
            f"need >= 4 host devices, got {jax.device_count()}"
        check_conformance_cells()
        sys.exit(0)
    assert jax.device_count() == 8, f"need 8 host devices, got {jax.device_count()}"
    check_1d_single_axis()
    check_1d_multi_axis()
    check_3d()
    check_3d_transposed()
    check_3d_multipod()
    check_1d_natural_roundtrip()
    check_conformance_cells()
    check_1d_roundtrip_c128()
    print("ALL DISTRIBUTED CHECKS PASSED")
