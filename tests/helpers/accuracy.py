"""Shared numeric-accuracy helpers for the whole test suite.

One place for the rel-L2 metric, the gearshifft tolerance policy, and the
numpy differential references, so the conformance matrix and the per-kernel
tests measure the same thing with the same bar instead of each module
carrying its own ad-hoc copy.
"""

from __future__ import annotations

import numpy as np

#: gearshifft-style roundtrip/forward accuracy bars (rel-L2 against a
#: float64 reference): single precision 1e-3, double precision 1e-8.
REL_L2_TOL = {"float": 1e-3, "double": 1e-8}


def rel_l2(got, want) -> float:
    """Relative L2 distance ||got - want|| / ||want|| (0-safe)."""
    got = np.asarray(got, dtype=np.complex128)
    want = np.asarray(want, dtype=np.complex128)
    return float(np.linalg.norm(got - want) /
                 max(np.linalg.norm(want), 1e-300))


def assert_rel_l2(got, want, precision: str = "float", what: str = "") -> None:
    err = rel_l2(got, want)
    tol = REL_L2_TOL[precision]
    assert err < tol, f"{what or 'output'}: rel_l2={err:.3e} >= {tol:g}"


def rand_input(problem, seed: int = 0) -> np.ndarray:
    """Random host input matching a Problem's dtype/shape (batch leading)."""
    rng = np.random.default_rng(seed)
    shape = (problem.batch, *problem.extents)
    x = rng.standard_normal(shape).astype(problem.real_dtype)
    if problem.complex_input:
        x = (x + 1j * rng.standard_normal(shape)).astype(problem.input_dtype)
    return x


def numpy_forward(problem, x: np.ndarray) -> np.ndarray:
    """float64 numpy reference of the forward transform over the problem's
    trailing axes (fftn for complex kinds, rfftn for real kinds)."""
    axes = tuple(range(-problem.rank, 0))
    if problem.complex_input:
        return np.fft.fftn(x.astype(np.complex128), axes=axes)
    return np.fft.rfftn(x.astype(np.float64), axes=axes)
