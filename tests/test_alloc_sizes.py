"""Device-buffer accounting: JaxFFTClient.get_alloc_size pinned for all
four kind x placement combinations (paper Table 1's get_alloc_size), with
the FFTW padded in-place r2c layout — an in-place real transform allocates
2*(n/2+1) reals along the last axis so the half-spectrum fits in place."""

import pytest

from repro.core.client import Context, Problem
from repro.core.clients.jax_fft import JaxFFTClient


def alloc(extents, kind, precision="float", batch=1):
    problem = Problem(tuple(extents), kind, precision, batch=batch)
    return JaxFFTClient(problem, Context()).get_alloc_size(), problem


def halfspec_bytes(extents, real_itemsize, batch=1):
    rows = batch
    for v in extents[:-1]:
        rows *= v
    return rows * (extents[-1] // 2 + 1) * 2 * real_itemsize


@pytest.mark.parametrize("extents", [(16,), (8, 16), (4, 4, 8), (8, 15)])
@pytest.mark.parametrize("precision,itemsize", [("float", 4), ("double", 8)])
def test_all_four_kind_placement_combinations(extents, precision, itemsize):
    n_elems = 1
    for v in extents:
        n_elems *= v

    # Outplace_Complex: signal + spectrum, both full complex
    got, p = alloc(extents, "Outplace_Complex", precision)
    assert got == 2 * n_elems * 2 * itemsize
    # Inplace_Complex: one full complex buffer
    got, p = alloc(extents, "Inplace_Complex", precision)
    assert got == n_elems * 2 * itemsize
    # Outplace_Real: real signal + half-spectrum buffer
    got, p = alloc(extents, "Outplace_Real", precision)
    assert got == n_elems * itemsize + halfspec_bytes(extents, itemsize)
    # Inplace_Real: FFTW padded layout — 2*(n/2+1) reals on the last axis,
    # NOT the unpadded signal size
    got, p = alloc(extents, "Inplace_Real", precision)
    assert got == halfspec_bytes(extents, itemsize)


def test_inplace_real_padding_exceeds_signal():
    """The padding is real: for even last extents the in-place allocation
    is one extra complex column wider than the input signal."""
    got, p = alloc((8, 16), "Inplace_Real")
    assert got == 8 * (16 // 2 + 1) * 2 * 4    # 8 rows x 9 bins x c64
    assert got > p.signal_bytes                # 576 > 512
    # odd last extent: 2*(15//2+1) = 16 reals per 15-real row
    got, p = alloc((8, 15), "Inplace_Real")
    assert got == 8 * 8 * 2 * 4 and got > p.signal_bytes


def test_batch_scales_every_kind():
    for kind in ("Inplace_Real", "Inplace_Complex",
                 "Outplace_Real", "Outplace_Complex"):
        one, _ = alloc((8, 16), kind, batch=1)
        four, _ = alloc((8, 16), kind, batch=4)
        assert four == 4 * one, kind
