"""Roofline module: table assembly robustness, the vlm parameter
accounting, and the FFT roofline helpers the bench grid annotates with."""

from __future__ import annotations

import builtins
import json
from dataclasses import replace

import pytest

from repro.configs.base import get_config
from repro.roofline import analysis
from repro.roofline.analysis import (
    DEVICE_PEAKS, HBM_BW, PEAK_FLOPS, active_params, device_peaks,
    fft_model_flops, fft_roofline_frac, load_rows, markdown_table,
    row_from_record,
)


def _rec(**over):
    rec = {"arch": "qwen3-1.7b", "shape": "train_4k", "mesh": "16x16",
           "status": "ok", "flops_per_device": 1e15,
           "dot_bytes_per_device": 1e12,
           "collectives": {"total_bytes": 1e9}, "compile_s": 1.0}
    rec.update(over)
    return rec


# ---------------------------------------------------------------------------
# table assembly
# ---------------------------------------------------------------------------
def test_unknown_mesh_becomes_skipped_row():
    # an unfamiliar dry-run mesh used to KeyError and abort the whole table
    row = row_from_record(_rec(mesh="4x4"))
    assert row.status == "skipped: unknown mesh 4x4"
    assert row.compute_s == 0.0
    # skipped rows render as a dash line, not a crash
    assert "skipped: unknown mesh 4x4" in markdown_table([row])


def test_known_mesh_row():
    row = row_from_record(_rec())
    assert row.status == "ok"
    assert row.compute_s == pytest.approx(1e15 / PEAK_FLOPS)
    assert row.memory_s == pytest.approx(1e12 / HBM_BW)
    assert row.dominant == "compute"
    assert row.roofline_fraction > 0


def test_load_rows_closes_file_handles(tmp_path, monkeypatch):
    for i in range(3):
        (tmp_path / f"r{i}.json").write_text(
            json.dumps(_rec(status="error")))
    opened = []
    real_open = builtins.open

    def tracking_open(*a, **kw):
        f = real_open(*a, **kw)
        opened.append(f)
        return f

    monkeypatch.setattr(builtins, "open", tracking_open)
    rows = load_rows(str(tmp_path), mesh=None)
    monkeypatch.undo()
    assert len(rows) == 3
    assert opened and all(f.closed for f in opened)


# ---------------------------------------------------------------------------
# vlm parameter accounting
# ---------------------------------------------------------------------------
def test_vlm_counts_cross_attention_layers():
    cfg = get_config("llama-3.2-vision-90b")
    total, active = active_params(cfg)
    # 100 layers = 80 self + 20 cross (every 5th); both layer kinds carry
    # q/k/v/o attention weights plus the gated MLP
    d, hd = cfg.d_model, cfg.head_dim
    attn = (d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd
            + cfg.n_heads * hd * d)
    mlp = 3 * d * cfg.d_ff
    expected = 80 * (attn + mlp) + 20 * (attn + mlp)
    assert total == active == expected
    assert total > 0


def test_vlm_cross_every_zero_is_all_self_attention():
    # guard: cross_every=0 must not divide by zero
    cfg = replace(get_config("llama-3.2-vision-90b"), cross_every=0)
    total, _ = active_params(cfg)
    d, hd = cfg.d_model, cfg.head_dim
    attn = (d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd
            + cfg.n_heads * hd * d)
    assert total == cfg.n_layers * (attn + 3 * d * cfg.d_ff)


# ---------------------------------------------------------------------------
# FFT roofline helpers
# ---------------------------------------------------------------------------
def test_device_peaks_prefix_match():
    assert device_peaks("TPU v4 (4 cores)") == DEVICE_PEAKS["tpu v4"]
    assert device_peaks("TPU v5 lite") == DEVICE_PEAKS["tpu v5 lite"]
    assert device_peaks("cpu") == DEVICE_PEAKS["cpu"]
    # unknown kinds fall back to the conservative cpu envelope
    assert device_peaks("NVIDIA H100") == DEVICE_PEAKS["cpu"]
    assert device_peaks(None) == DEVICE_PEAKS["cpu"]


def test_fft_model_flops():
    assert fft_model_flops((1024,)) == pytest.approx(5.0 * 1024 * 10)
    # nd flops depend only on total N (sum of per-axis log2 terms)
    assert fft_model_flops((32, 32)) == fft_model_flops((1024,))
    assert fft_model_flops((1024,), batch=4) == \
        pytest.approx(4 * fft_model_flops((1024,)))
    assert fft_model_flops((1,)) == 0.0
    assert fft_model_flops(()) == 0.0


def test_fft_roofline_frac_finite():
    peak_flops, hbm_bw = device_peaks("cpu")
    # memory-bound: bytes term dominates
    frac = fft_roofline_frac(1.0, 1e6, 2e7, "cpu")
    assert frac == pytest.approx((2e7 / hbm_bw) / 1e-3)
    # infeasible-candidate byte sentinel must not poison the fraction
    frac = fft_roofline_frac(1.0, 1e9, float("inf"), "cpu")
    assert frac == pytest.approx((1e9 / peak_flops) / 1e-3)
    # no model at all -> 0, never NaN
    assert fft_roofline_frac(1.0, 0.0, float("inf"), "cpu") == 0.0
    assert fft_roofline_frac(0.0, 1e9, 1e6, "cpu") == 0.0
