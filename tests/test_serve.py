"""FFT serving layer: queue/coalescer mechanics, end-to-end correctness
against numpy, timeout/error robustness, traffic replay determinism, the
percentile plumbing, and concurrency hammers for the shared PlanCache and
wisdom store."""

from __future__ import annotations

import json
import threading
import time

import numpy as np
import pytest

from repro.core.client import Problem
from repro.core.plan import Candidate, Plan, PlanCache, PlanRigor
from repro.core.results import (aggregate_rows, percentile,
                                percentile_summary, Row)
from repro.core.wisdom import Wisdom
from repro.serve import (Coalescer, FaultPlan, FFTService, QueueFull,
                         RequestQueue, RequestTimeout, ServeConfig,
                         ServeError, TrafficSpec, WorkerWedged, chaos_replay,
                         make_request, replay)


def _payload(ext=(64,), rows=None, dtype=np.complex64, seed=0):
    """A transform input: shape ``ext``, or ``(rows, *ext)`` when a request
    should occupy several batch rows (submit those with ``rank=len(ext)``)."""
    rng = np.random.default_rng(seed)
    shape = ext if rows is None else (rows, *ext)
    x = rng.standard_normal(shape)
    if np.issubdtype(dtype, np.complexfloating):
        x = x + 1j * rng.standard_normal(shape)
    return x.astype(dtype)


def _service(**kw):
    kw.setdefault("coalesce_window_ms", 2.0)
    kw.setdefault("max_batch", 8)
    return FFTService(config=ServeConfig(**kw))


# ---------------------------------------------------------------------------
# percentile math (results.py satellite)
# ---------------------------------------------------------------------------
def test_percentile_matches_numpy_linear_interpolation():
    rng = np.random.default_rng(42)
    vals = list(rng.standard_normal(37) * 10)
    for q in (0, 25, 50, 75, 95, 99, 100):
        assert percentile(vals, q) == pytest.approx(
            float(np.percentile(vals, q)), rel=1e-12)


def test_percentile_summary_keys_and_single_sample():
    s = percentile_summary([3.0])
    assert s == {"p50": 3.0, "p95": 3.0, "p99": 3.0}
    assert percentile([1.0, 2.0], 50) == pytest.approx(1.5)


def test_aggregate_rows_percentiles_opt_in_preserves_default_shape():
    rows = [Row(library="L", device="d", extents="8", rank=1,
                extent_class="powerof2", precision="float",
                kind="Outplace_Complex", rigor="estimate", run=i,
                op="execute_forward", time_ms=float(i + 1), bytes=0)
            for i in range(10)]
    default = aggregate_rows(rows, op="execute_forward")
    assert len(default[0]) == 9                      # legacy 9-tuple intact
    wide = aggregate_rows(rows, op="execute_forward", percentiles=True)
    (*key, mean, sd, p50, p95, p99, n) = wide[0]
    assert n == 10 and mean == pytest.approx(5.5)
    assert p50 == pytest.approx(np.percentile(range(1, 11), 50))
    assert p99 == pytest.approx(np.percentile(range(1, 11), 99))


# ---------------------------------------------------------------------------
# request + queue mechanics
# ---------------------------------------------------------------------------
def test_make_request_infers_precision_and_rank():
    req = make_request(_payload((16,), dtype=np.complex128))
    assert req.precision == "double" and req.extents == (16,)
    assert req.rows == 1
    req = make_request(_payload((4, 8), rows=2), rank=2)
    assert req.extents == (4, 8) and req.rows == 2
    with pytest.raises(ValueError):
        make_request(np.zeros((4,), np.int32))


def test_queue_backpressure_and_load_shed():
    q = RequestQueue(maxsize=2)
    q.put(make_request(_payload()))
    q.put(make_request(_payload()))
    with pytest.raises(QueueFull):
        q.put(make_request(_payload()), block=False)
    with pytest.raises(QueueFull):
        q.put(make_request(_payload()), timeout=0.01)
    assert q.get(timeout=0.01) is not None
    q.put(make_request(_payload()), block=False)    # space again


def test_queue_put_many_is_all_or_nothing():
    q = RequestQueue(maxsize=3)
    q.put_many([make_request(_payload()) for _ in range(3)])
    with pytest.raises(QueueFull):
        q.put_many([make_request(_payload())], block=False)
    assert len(q) == 3
    q.close()
    with pytest.raises(QueueFull):
        q.put_many([make_request(_payload())])


def test_queue_close_drains_then_none():
    q = RequestQueue()
    q.put(make_request(_payload()))
    q.close()
    assert q.get(timeout=0.1) is not None   # drain what remains
    assert q.get(timeout=0.1) is None       # then the shutdown signal


def test_coalescer_groups_same_plan_only():
    q = RequestQueue()
    a1 = make_request(_payload((32,)))
    b = make_request(_payload((64,)))
    a2 = make_request(_payload((32,)))
    for r in (a1, b, a2):
        q.put(r)
    c = Coalescer(q, window_ms=0.0, max_rows=8)
    batch = c.next_batch()
    assert [r.rid for r in batch.requests] == [a1.rid, a2.rid]
    assert batch.rows == 2 and batch.extents == (32,)
    assert c.next_batch().requests == [b]


def test_coalescer_respects_row_budget():
    q = RequestQueue()
    reqs = [make_request(_payload((16,), rows=2), rank=1) for _ in range(4)]
    for r in reqs:
        q.put(r)
    c = Coalescer(q, window_ms=0.0, max_rows=5)
    batch = c.next_batch()
    assert batch.rows == 4 and batch.n_requests == 2   # 3rd would exceed 5
    assert c.next_batch().rows == 4


def test_serial_fifo_when_coalescing_disabled():
    q = RequestQueue()
    reqs = [make_request(_payload((16,))) for _ in range(3)]
    for r in reqs:
        q.put(r)
    c = Coalescer(q, window_ms=0.0, max_rows=1)
    got = [c.next_batch().requests[0].rid for _ in range(3)]
    assert got == [r.rid for r in reqs]


# ---------------------------------------------------------------------------
# end-to-end service correctness
# ---------------------------------------------------------------------------
def test_service_burst_matches_numpy_and_coalesces():
    xs = [_payload((128,), seed=i) for i in range(6)]
    with _service(coalesce_window_ms=10.0) as svc:
        reqs = [svc.submit(x) for x in xs]
        outs = [np.asarray(r.result(timeout=300)) for r in reqs]
    for x, y in zip(xs, outs):
        ref = np.fft.fft(x)
        assert np.max(np.abs(y[0] - ref)) / np.max(np.abs(ref)) < 1e-3
    rep = svc.report()
    assert rep["completed"] == 6 and rep["errors"] == 0
    assert rep["batches"] < 6 and rep["coalesce_rate"] > 0
    assert {"p50", "p95", "p99"} <= set(rep["latency_ms"])


def test_service_mixed_shapes_kinds_precisions():
    jobs = [
        (_payload((64,), dtype=np.complex64), "Outplace_Complex"),
        (_payload((32, 16), dtype=np.complex128), "Outplace_Complex"),
        (_payload((64,), dtype=np.float32), "Outplace_Real"),
        (_payload((128,), dtype=np.float64), "Outplace_Real"),
    ]
    with _service() as svc:
        reqs = [svc.submit(x, kind=k) for x, k in jobs]
        outs = [np.asarray(r.result(timeout=300)) for r in reqs]
    for (x, kind), y in zip(jobs, outs):
        if kind == "Outplace_Complex":
            ref = np.fft.fftn(x.astype(np.complex128))
        else:
            ref = np.fft.rfftn(x.astype(np.float64))
        tol = 1e-3 if x.dtype.itemsize <= 8 else 1e-9
        assert np.max(np.abs(y[0] - ref)) / np.max(np.abs(ref)) < tol


def test_submit_many_returns_futures_in_order():
    xs = [_payload((32,), seed=i) for i in range(5)]
    with _service() as svc:
        reqs = svc.submit_many(xs)
        outs = [np.asarray(r.result(timeout=300)) for r in reqs]
    for x, y in zip(xs, outs):
        assert np.allclose(y[0], np.fft.fft(x), rtol=1e-3, atol=1e-3)


def test_request_timeout_fails_cleanly_and_worker_survives():
    with _service(timeout_ms=0.0) as svc:      # every request pre-expired
        req = svc.submit(_payload((32,)))
        with pytest.raises(RequestTimeout):
            req.result(timeout=60)
        # the worker must still serve fresh (un-expired) work
        ok = svc.submit(_payload((32,)), timeout_ms=60_000)
        assert ok.result(timeout=300) is not None
    rep = svc.report()
    assert rep["timeouts"] == 1 and rep["completed"] == 1
    failed = [r for r in svc.rows() if not r.success]
    assert len(failed) == 1 and "expired" in failed[0].error


def test_engine_error_fails_batch_not_worker():
    with _service(backend="fft2_pallas") as svc:   # rank-2 only: 1D must fail
        bad = svc.submit(_payload((32,)))
        with pytest.raises(ServeError, match="engine error"):
            bad.result(timeout=300)
        good = svc.submit(_payload((8, 8), dtype=np.complex64))
        assert good.result(timeout=300) is not None
    assert svc.report()["errors"] == 1


def test_submit_validates_rows_and_started():
    svc = _service(max_batch=2)
    with pytest.raises(ServeError, match="not started"):
        svc.submit(_payload((16,)))
    with svc:
        with pytest.raises(ServeError, match="exceed max_batch"):
            svc.submit(_payload((16,), rows=4), rank=1)


def test_prewarm_compiles_bucket_ladder():
    with _service(max_batch=8) as svc:
        n = svc.prewarm((32,))
        assert n == 4                         # buckets 1, 2, 4, 8
        stats = svc.session.plan_cache.stats
        misses0 = stats.misses
        svc.submit(_payload((32,))).result(timeout=300)
        assert stats.misses == misses0        # served entirely warm


def test_serve_config_roundtrip_and_validation():
    cfg = ServeConfig(max_batch=4, workers=2, backend="xla")
    assert ServeConfig.from_dict(cfg.to_dict()) == cfg
    with pytest.raises(ValueError, match="unknown ServeConfig"):
        ServeConfig.from_dict({"max_batch": 4, "nope": 1})
    with pytest.raises(ValueError):
        ServeConfig(max_batch=0)
    with pytest.raises(ValueError):
        ServeConfig(rigor="bogus")


# ---------------------------------------------------------------------------
# fault tolerance: fallback, retry, bisection, watchdog, wedge detection
# ---------------------------------------------------------------------------
def test_engine_falls_back_past_compile_fault_and_persists_demotion(tmp_path):
    from repro.core.plan import fallback_chain
    from repro.core.client import Problem

    top = fallback_chain(Problem((64,), "Outplace_Complex", "float")).pop(0)
    wisdom = Wisdom(str(tmp_path / "wisdom.json"), device_kind="cpu")
    svc = FFTService(config=ServeConfig(max_batch=8, breaker_threshold=1),
                     wisdom=wisdom,
                     fault_plan=FaultPlan([{"fault": "compile_error",
                                            "backend": top.backend}]))
    with svc:
        x = _payload((64,))
        out = np.asarray(svc.submit(x).result(timeout=300))
    assert np.allclose(out[0], np.fft.fft(x), rtol=1e-3, atol=1e-3)
    rep = svc.report()
    assert rep["completed"] == 1 and rep["errors"] == 0
    assert rep["demotions"] >= 1 and rep["faults_injected"] >= 1
    # the quarantine shows up in the report and survived to wisdom on disk
    assert any(k.startswith(top.backend) and v["state"] == "open"
               for k, v in rep["quarantine"].items())
    fresh = Wisdom(str(tmp_path / "wisdom.json"), device_kind="cpu")
    assert top.backend in fresh.demoted(
        Problem((64,), "Outplace_Complex", "float"))


def test_poison_request_fails_alone_batchmates_succeed():
    xs = [_payload((32,), seed=i) for i in range(4)]
    reqs = [make_request(x) for x in xs]
    poison = reqs[1]
    svc = _service(coalesce_window_ms=20.0)
    svc.fault_plan = FaultPlan([{"fault": "execute_error",
                                 "rid": poison.rid}])
    with svc:
        svc.queue.put_many(reqs)      # one coalesced batch, rids known
        with pytest.raises(ServeError, match="injected execute error"):
            poison.result(timeout=300)
        for i, req in enumerate(reqs):
            if req is poison:
                continue
            out = np.asarray(req.result(timeout=300))
            ref = np.fft.fft(xs[i])
            assert np.max(np.abs(out[0] - ref)) / np.max(np.abs(ref)) < 1e-2
    rep = svc.report()
    assert rep["completed"] == 3 and rep["errors"] == 1
    assert rep["bisections"] >= 2     # 4 -> 2+2 -> 1+1: poison isolated


def test_transient_fault_recovered_by_retry():
    svc = _service(faults=({"fault": "execute_error", "times": 2},),
                   max_retries=3)
    with svc:
        req = svc.submit(_payload((32,)))
        out = np.asarray(req.result(timeout=300))
    assert out is not None and req.ok and req.attempts >= 1
    rep = svc.report()
    assert rep["completed"] == 1 and rep["errors"] == 0
    assert rep["retries"] >= 1 and rep["retry_successes"] >= 1
    assert rep["faults_injected"] == 2


def test_kill_worker_watchdog_restarts_and_service_survives():
    svc = _service(faults=({"fault": "kill_worker", "times": 1},),
                   watchdog_interval_s=0.05)
    with svc:
        doomed = svc.submit(_payload((32,)))
        with pytest.raises(ServeError, match="failed by watchdog"):
            doomed.result(timeout=60)
        ok = svc.submit(_payload((32,)))     # the restarted worker serves it
        assert ok.result(timeout=300) is not None
    rep = svc.report()
    assert rep["worker_restarts"] >= 1 and rep["completed"] == 1
    assert any("WorkerKilled" in e for e in rep["worker_errors"])
    assert rep["wedged"] == 0


def test_stop_reports_wedged_worker():
    svc = _service(faults=({"fault": "transfer_stall", "stall_ms": 3000.0,
                            "times": 1},),
                   join_timeout_s=0.2, drain_timeout_s=0.2,
                   watchdog_interval_s=0.0)
    svc.start()
    req = svc.submit(_payload((32,)))
    time.sleep(0.1)                   # let the worker enter the stall
    with pytest.raises(WorkerWedged, match="failed to join") as ei:
        svc.stop()
    assert ei.value.snapshot["wedged_workers"]
    assert ei.value.snapshot["wedged"] >= 1
    req.result(timeout=60)            # the stalled worker still finishes it


def test_failure_messages_carry_actionable_context():
    q = RequestQueue(maxsize=2)
    q.put(make_request(_payload()))
    q.put(make_request(_payload()))
    with pytest.raises(QueueFull, match=r"2/2 requests pending"):
        q.put(make_request(_payload()), block=False)
    with pytest.raises(QueueFull, match=r"after waiting 0.01s"):
        q.put(make_request(_payload()), timeout=0.01)
    with _service(timeout_ms=0.0) as svc:
        req = svc.submit(_payload((32,)))
        with pytest.raises(RequestTimeout, match=r"0 ms deadline"):
            req.result(timeout=60)
    assert "queue depth" in str(req.error)


def test_serve_config_fault_fields_roundtrip_and_validation():
    cfg = ServeConfig(max_retries=5, breaker_threshold=2,
                      faults=({"fault": "latency_spike", "stall_ms": 1.0},))
    assert ServeConfig.from_dict(cfg.to_dict()) == cfg
    assert "faults" not in ServeConfig().to_dict()
    with pytest.raises(ValueError):
        ServeConfig(max_retries=-1)
    with pytest.raises(ValueError):
        ServeConfig(breaker_threshold=0)
    with pytest.raises(ValueError, match="unknown fault"):
        ServeConfig(faults=({"fault": "gremlins"},))


def test_chaos_replay_grades_recovery(tmp_path):
    from repro.core.plan import fallback_chain
    from repro.core.client import Problem

    top = fallback_chain(Problem((64,), "Outplace_Complex", "float")).pop(0)
    spec = TrafficSpec(extents=((64,), (32,)), requests=10, seed=11,
                       faults=({"fault": "compile_error",
                                "backend": top.backend},
                               {"fault": "execute_error", "after": 1,
                                "times": 1}))
    svc = FFTService(config=ServeConfig(coalesce_window_ms=2.0, max_batch=8,
                                        breaker_threshold=1))
    with svc:
        rep = chaos_replay(svc, spec)
    assert rep.ok, rep.violations
    assert rep.total == 10 and rep.poisoned == 0
    assert rep.clean_success_rate == 1.0
    assert rep.faults["injected"] >= 2
    assert rep.replay.service["demotions"] >= 1
    json.dumps(rep.to_dict())


# ---------------------------------------------------------------------------
# traffic replay
# ---------------------------------------------------------------------------
def test_traffic_spec_roundtrip_and_validation():
    spec = TrafficSpec(extents=("256", (64, 64)), requests=10, rate_hz=50.0)
    assert spec.extents == ((256,), (64, 64))
    assert TrafficSpec.from_dict(spec.to_dict()) == spec
    with pytest.raises(ValueError, match="unknown TrafficSpec"):
        TrafficSpec.from_dict({"requests": 5, "bogus": 1})
    with pytest.raises(ValueError):
        TrafficSpec(kinds=("Sideways_Complex",))
    with pytest.raises(ValueError):
        TrafficSpec(requests=0)


def test_traffic_schedule_deterministic_and_zipf_skewed():
    spec = TrafficSpec(extents=((32,), (64,), (128,)), requests=200, seed=9)
    tape1, tape2 = list(spec.schedule()), list(spec.schedule())
    assert tape1 == tape2
    counts = {}
    for _, ext, _, _ in tape1:
        counts[ext] = counts.get(ext, 0) + 1
    assert counts[(32,)] > counts[(128,)]     # rank-1 entry is the hot one
    # burst mode: all arrivals at t=0
    assert all(t == 0.0 for t, *_ in tape1)


def test_replay_end_to_end_report():
    spec = TrafficSpec(extents=((32,), (64,)), requests=12, rate_hz=0.0,
                       seed=5)
    with _service(coalesce_window_ms=5.0) as svc:
        rep = replay(svc, spec)
    assert rep.service["completed"] == 12
    assert rep.service["batches"] < 12        # burst traffic must coalesce
    assert {"p50", "p95", "p99"} <= set(rep.service["latency_ms"])
    assert sum(m["requests"] for m in rep.per_mix) == 12
    json.dumps(rep.to_dict())                 # report is JSON-clean


def test_replay_through_result_set_summary():
    spec = TrafficSpec(extents=((32,),), requests=6, seed=1)
    with _service() as svc:
        replay(svc, spec)
    summary = svc.result_set().summary(latency_op="serve_request")
    assert summary["latency_ms"]["n"] == 6
    assert {"p50", "p95", "p99"} <= set(summary["latency_ms"])


# ---------------------------------------------------------------------------
# ServeFFT through the ordinary suite
# ---------------------------------------------------------------------------
def test_serve_client_through_run_suite():
    from repro.core.client import Context
    from repro.core.suite import Session, SuiteSpec

    spec = SuiteSpec(clients=("ServeFFT",), extents=((64,),),
                     kinds=("Outplace_Complex", "Outplace_Real"),
                     precisions=("float",), warmups=0, repetitions=2,
                     output=None)
    rs = Session(context=Context({"serve_burst": 3})).run(spec)
    assert rs.n_failures == 0
    ops = {r.op for r in rs.rows}
    assert "execute_forward" in ops and "init_inverse" not in ops
    wide = rs.aggregate(op="execute_forward", percentiles=True)
    assert len(wide[0]) == 12                 # percentile columns present


# ---------------------------------------------------------------------------
# concurrency hammers: shared PlanCache + wisdom store
# ---------------------------------------------------------------------------
def _hammer(n_threads, fn):
    errors = []
    barrier = threading.Barrier(n_threads)

    def work(i):
        try:
            barrier.wait(timeout=30)
            fn(i)
        except Exception as e:             # surface, don't swallow
            errors.append(e)

    threads = [threading.Thread(target=work, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
    assert not errors, errors
    assert not any(t.is_alive() for t in threads)


def test_plan_cache_hammer_single_flight_invariants():
    cache = PlanCache()
    keys = [("exec", k) for k in range(4)]
    builds = []
    build_lock = threading.Lock()
    n_threads, per_thread = 8, 20

    def work(i):
        rng = np.random.default_rng(i)
        for _ in range(per_thread):
            key = keys[int(rng.integers(len(keys)))]

            def build():
                with build_lock:
                    builds.append(key)
                time.sleep(0.001)          # widen the race window
                return object()

            obj, _, _ = cache.executable(key, build)
            assert obj is not None

    _hammer(n_threads, work)
    # single-flight: each key built exactly once, no lost updates
    assert len(builds) == len(keys)
    assert set(builds) == set(keys)
    stats = cache.stats
    assert stats.misses == len(keys)
    assert stats.hits + stats.misses == n_threads * per_thread
    assert len(cache) == len(keys)


def test_plan_cache_hammer_plan_lookups():
    cache = PlanCache()
    problem = Problem((64,), "Outplace_Complex", "float")
    built = []

    def make():
        built.append(1)
        time.sleep(0.001)
        return Plan(problem, Candidate("xla"), PlanRigor.ESTIMATE, 0.0)

    plans = []

    def work(i):
        plan, _ = cache.plan(("plan", "k"), make)
        plans.append(plan)

    _hammer(8, work)
    assert len(built) == 1                 # one builder, 7 waiters
    assert all(p is plans[0] for p in plans)


def test_wisdom_hammer_concurrent_record_and_save(tmp_path):
    path = tmp_path / "wisdom.json"
    w = Wisdom(str(path), device_kind="cpu")
    n_threads = 6

    def work(i):
        for j in range(10):
            p = Problem((64 * (i + 1),), "Outplace_Complex", "float",
                        batch=j % 3 + 1)
            w.record(p, Candidate("xla"))
            w.save()                       # interleaved atomic merges

    _hammer(n_threads, work)
    # the file is valid JSON and a fresh load sees every key
    with open(path) as f:
        json.load(f)
    fresh = Wisdom(str(path), device_kind="cpu")
    for i in range(n_threads):
        for b in (1, 2, 3):
            p = Problem((64 * (i + 1),), "Outplace_Complex", "float", batch=b)
            assert fresh.lookup(p) is not None, p.signature()


def test_service_hammer_many_submitters_one_cache():
    """N producer threads against one service: shared PlanCache misses stay
    bounded by the distinct (plan, bucket) set and every request completes."""
    n_threads, per_thread = 4, 5
    results = {}
    lock = threading.Lock()
    with _service(coalesce_window_ms=1.0, max_batch=8) as svc:
        def work(i):
            for j in range(per_thread):
                x = _payload((32,) if i % 2 else (64,), seed=i * 100 + j)
                out = np.asarray(svc.submit(x).result(timeout=300))
                ref = np.fft.fft(x)
                with lock:
                    results[(i, j)] = np.max(np.abs(out[0] - ref))

        _hammer(n_threads, work)
    assert len(results) == n_threads * per_thread
    assert all(v < 1e-2 for v in results.values())
    rep = svc.report()
    assert rep["completed"] == n_threads * per_thread
    assert rep["errors"] == 0 and rep["timeouts"] == 0
    # 2 plans x pow2 buckets <= 8 -> at most 8 distinct executables
    assert rep["plan_cache"]["misses"] <= 8
